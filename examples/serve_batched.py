"""Batched serving example: prefill + greedy decode on a smoke config.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-1.3b]
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    args = ap.parse_args()
    subprocess.run([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        "--batch", "4", "--prompt-len", "32", "--gen", "16",
    ], check=True)
