"""Crash-recovery demo across all three PCS layers (DESIGN.md §2).

  A — untimed oracle: the exact PB state machine loses power mid-drain;
      recovery (Section V-D4) re-drains every surviving entry and no
      acked version is lost.
  C — timed engine:   the same power loss as a traced ``crash_at_ns``
      scalar; the durability snapshot shows acked == durable and the
      modeled drain-all recovery cost.
  B — checkpoint tier: a training job persists shards, the process
      crashes at a deterministic persist index (``schedule_crash``),
      recovery re-drains the surviving buffer entries and the resume
      restores the acked prefix (read forwarding from the buffer tier).

    PYTHONPATH=src python examples/crash_recovery_demo.py

Runs in seconds; also exercised by ``benchmarks/run.py --smoke`` so it
cannot rot.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import PCSConfig, Scheme, fuzz_crash_ns, fuzz_trace
from repro.core.engine import simulate
from repro.core.semantics import EventKind, PersistentBuffer
from repro.launch.train import restore_state, save_state
from repro.optim import AdamWConfig, adamw_init
from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)


def demo_oracle() -> None:
    print("== Layer A: untimed oracle (core.semantics) ==")
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB_RF, n_pbe=4))
    acked = {}
    for i, addr in enumerate([0, 1, 2, 0, 3, 1]):
        for e in pb.persist(addr, f"{addr}@v{i}"):
            if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                acked[e.addr] = max(acked.get(e.addr, -1), e.version)
    # power loss with every drain still in flight
    pb.crash()
    events = pb.recover()
    redrained = sum(1 for e in events if e.kind == EventKind.DRAIN_SENT)
    print(f"acked {len(acked)} lines, crashed mid-drain, "
          f"recovery re-drained {redrained} surviving entries")
    for addr, ver in acked.items():
        rec = pb.pm.read(addr)
        assert rec is not None and rec[0] >= ver, f"acked {addr} lost"
    print("no acked version lost: OK")


def demo_engine() -> None:
    print("== Layer C: timed engine (crash_at_ns) ==")
    trace, _ = fuzz_trace(7, n_cores=3, n_slots=40, n_addrs=8)
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_pbe=8)
    full = simulate(trace, cfg, bucket=128, track_addrs=8)
    crashed = simulate(trace, cfg.with_crash(fuzz_crash_ns(20)),
                       bucket=128, track_addrs=8)
    print(f"full run: {full.persists} persists; "
          f"crash at slot 20: {crashed.persists} issued, "
          f"{crashed.acked_persists} acked, "
          f"{crashed.durable_persists} durable")
    assert crashed.acked_persists <= crashed.durable_persists
    print(f"recovery: {crashed.recovery_entries} surviving PBEs, "
          f"drain-all {crashed.recovery_ns:.0f} ns; durable versions "
          f"{np.asarray(crashed.durable_ver).tolist()}")
    print("acked => durable at every crash point: OK")


def demo_checkpoint_tier() -> None:
    print("== Layer B: checkpoint tier (persistence.manager) ==")
    params = {"w": jnp.ones((16, 16)), "b": jnp.zeros((16,))}
    opt = adamw_init(AdamWConfig(), params)
    with tempfile.TemporaryDirectory() as d:
        buf = HostBufferTier(capacity_bytes=64 << 20)
        store = DurableStore(d + "/store", write_delay_s=0.01)
        mgr = PCSCheckpointManager(buf, store, scheme=PersistScheme.PB_RF)
        t = save_state(mgr, 4, params, opt, {"step": 4})
        print(f"persisted v4 in {t:.3f}s (ack-at-buffer; store writes "
              f"continue in background)")
        # power loss right before the *next* save's first shard
        n_shards = mgr.stats["persists"]
        mgr.schedule_crash(n_shards)
        save_state(mgr, 5, params, opt, {"step": 5})   # dropped: power off
        print(f"CRASH after {n_shards} acked shard persists; "
              f"{mgr.stats['lost_after_crash']} v5 shards lost with power")
        n = mgr.recover()
        print(f"recovered: {n} surviving buffer entries re-drained")
        rec = restore_state(mgr, params, opt)
        assert rec is not None and rec[0] == 4, rec
        print(f"resumed at v{rec[0]} "
              f"(read-forwarded={mgr.stats['restore_forwarded']}, "
              f"from-store={mgr.stats['restore_from_store']})")
        mgr.close()


def main() -> None:
    demo_oracle()
    demo_engine()
    demo_checkpoint_tier()
    print("OK")


if __name__ == "__main__":
    main()
