"""Crash-recovery demo: train, crash mid-drain, recover, resume.

Shows the three PCS guarantees end to end on the checkpoint tier:
  * ack-at-buffer (persist returns before the store write lands),
  * crash consistency (recovery re-drains surviving buffer entries),
  * read forwarding (the resume restores from the buffer tier).

    PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.launch.train import restore_state, save_state
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)

if __name__ == "__main__":
    cfg = get_config("gemma2-2b", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10)
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(opt_cfg, params)
    data = SyntheticLMDataset(cfg.vocab, 32, 2)
    step = jax.jit(make_train_step(cfg, opt_cfg))

    with tempfile.TemporaryDirectory() as d:
        buf = HostBufferTier(capacity_bytes=256 << 20)
        store = DurableStore(d + "/store", write_delay_s=0.02)
        mgr = PCSCheckpointManager(buf, store, scheme=PersistScheme.PB_RF)

        for i in range(4):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            params, opt, m = step(params, opt, batch)
        t = save_state(mgr, 4, params, opt, data.state())
        print(f"persisted v4 in {t:.3f}s (ack-at-buffer; "
              f"store writes continue in background)")

        print("CRASH: drainer killed, in-flight drains lost")
        mgr.crash()
        n = mgr.recover()
        print(f"recovered: {n} surviving buffer entries re-drained to store")

        mgr2 = PCSCheckpointManager(buf, store, scheme=PersistScheme.PB_RF)
        rec = restore_state(mgr2, params, opt)
        assert rec is not None and rec[0] == 4
        print(f"resumed at v{rec[0]} "
              f"(read-forwarded={mgr2.stats['restore_forwarded']}, "
              f"from-store={mgr2.stats['restore_from_store']})")
        mgr2.close()
        print("OK")
