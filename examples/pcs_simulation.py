"""Reproduce the paper's headline numbers programmatically.

Runs the selected workloads under all three schemes and prints speedups,
persist/read latencies and the RF hit/coalesce rates (Figs 5-7).  The
whole {workload x scheme} grid — schemes mixed — is ONE ``simulate_grid``
call and therefore one XLA compilation: the scheme id is a traced
scalar, not a compile-time static.

    PYTHONPATH=src python examples/pcs_simulation.py [--quick]
"""
import argparse

from repro.core import PCSConfig, Scheme, make_trace, simulate_grid

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workloads", nargs="+",
                    default=["radiosity", "cholesky", "fft"])
    args = ap.parse_args()
    budget = 8_000 if args.quick else 100_000

    schemes = (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)
    traces = [make_trace(n, persist_budget=budget) for n in args.workloads]
    grid = simulate_grid(traces, [PCSConfig(scheme=s) for s in schemes])

    for tr, row in zip(traces, grid):
        nopb, pb, rf = row
        print(f"\n=== {tr.name} ({tr.total_ops} ops) ===")
        print(f"  speedup:   PB {100*(nopb.runtime_ns/pb.runtime_ns-1):+.1f}%"
              f"   PB_RF {100*(nopb.runtime_ns/rf.runtime_ns-1):+.1f}%")
        print(f"  persist:   NoPB {nopb.persist_lat_ns:.0f}ns -> "
              f"PB {pb.persist_lat_ns:.0f}ns "
              f"({100*pb.persist_lat_ns/nopb.persist_lat_ns:.0f}%)")
        print(f"  read:      NoPB {nopb.read_lat_ns:.0f}ns -> "
              f"PB {pb.read_lat_ns:.0f}ns "
              f"({100*pb.read_lat_ns/nopb.read_lat_ns:.0f}%)")
        print(f"  RF:        hit {100*rf.read_hit_rate:.1f}%  "
              f"coalesce {100*rf.coalesce_rate:.1f}%")
