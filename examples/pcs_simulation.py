"""Reproduce the paper's headline numbers programmatically.

Runs the radiosity (best case) and cholesky (worst case) workloads under
all three schemes and prints speedups, persist/read latencies and the RF
hit/coalesce rates (Figs 5-7).

    PYTHONPATH=src python examples/pcs_simulation.py [--quick]
"""
import argparse

from repro.core import PCSConfig, Scheme, make_trace, simulate

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workloads", nargs="+",
                    default=["radiosity", "cholesky", "fft"])
    args = ap.parse_args()
    budget = 8_000 if args.quick else 100_000

    for name in args.workloads:
        tr = make_trace(name, persist_budget=budget)
        res = {s: simulate(tr, PCSConfig(scheme=s))
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)}
        nopb, pb, rf = (res[s] for s in (Scheme.NOPB, Scheme.PB,
                                         Scheme.PB_RF))
        print(f"\n=== {name} ({tr.total_ops} ops) ===")
        print(f"  speedup:   PB {100*(nopb.runtime_ns/pb.runtime_ns-1):+.1f}%"
              f"   PB_RF {100*(nopb.runtime_ns/rf.runtime_ns-1):+.1f}%")
        print(f"  persist:   NoPB {nopb.persist_lat_ns:.0f}ns -> "
              f"PB {pb.persist_lat_ns:.0f}ns "
              f"({100*pb.persist_lat_ns/nopb.persist_lat_ns:.0f}%)")
        print(f"  read:      NoPB {nopb.read_lat_ns:.0f}ns -> "
              f"PB {pb.read_lat_ns:.0f}ns "
              f"({100*pb.read_lat_ns/nopb.read_lat_ns:.0f}%)")
        print(f"  RF:        hit {100*rf.read_hit_rate:.1f}%  "
              f"coalesce {100*rf.coalesce_rate:.1f}%")
