"""Quickstart: train a reduced-config model with PCS-tier checkpointing.

    PYTHONPATH=src python examples/quickstart.py
"""
import subprocess
import sys
import tempfile

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        subprocess.run([
            sys.executable, "-m", "repro.launch.train",
            "--arch", "smollm-135m", "--smoke",
            "--steps", "20", "--batch", "4", "--seq", "64",
            "--ckpt-every", "5", "--ckpt-dir", d,
            "--scheme", "pb_rf",
        ], check=True)
