# Tests and benches must see the real (single) CPU device; only the
# dry-run module sets --xla_force_host_platform_device_count=512, and it
# does so before any jax import inside its own process.
import os

import pytest

assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""), (
    "run pytest without the dry-run's XLA_FLAGS; smoke tests expect 1 device")

# Persistent XLA compile cache: the suite is dominated by compiles of the
# same engine programs run after run, so cache them across processes.
# First run pays the compiles; warm runs skip the XLA backend work.
# Override (or disable with an empty value) via JAX_COMPILATION_CACHE_DIR.
if "JAX_COMPILATION_CACHE_DIR" not in os.environ:
    os.environ["JAX_COMPILATION_CACHE_DIR"] = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".jax_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running (big-model smoke, exhaustive grids); "
        "excluded from `make test`, included in `make test-all` / tier-1")


# --------------------------------------------------------------------------
# Shared tiny-trace set + the one-compilation paper grid.
#
# XLA recompiles dominated the suite (every distinct trace shape built its
# own program); these session-scoped fixtures build the 7 paper workloads
# once at a reduced persist budget and run the whole mixed-scheme
# {workload x scheme} grid through ONE compiled simulate_grid program that
# every engine test then shares.
# --------------------------------------------------------------------------
TINY_BUDGET = 200
TINY_BUCKET = 512
TINY_TRACE_KW = {"fft": {"m": 9}}   # shrink the FFT read volume


@pytest.fixture(scope="session")
def tiny_traces():
    from repro.core import WORKLOADS, make_trace
    return {name: make_trace(name, persist_budget=TINY_BUDGET,
                             **TINY_TRACE_KW.get(name, {}))
            for name in WORKLOADS}


@pytest.fixture(scope="session")
def paper_grid(tiny_traces):
    """One compiled {7 workloads x NoPB/PB/PB_RF} grid, shared by tests.

    Returns ``(names, configs, cells, compiles)`` where ``compiles`` is
    the number of XLA programs the grid cost (the one-program acceptance
    test asserts it is exactly 1).
    """
    from repro.core import PCSConfig, Scheme, simulate_grid
    from repro.core.engine import compile_count

    names = list(tiny_traces)
    traces = [tiny_traces[n] for n in names]
    configs = [PCSConfig(scheme=s)
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)]
    c0 = compile_count()
    cells = simulate_grid(traces, configs, bucket=TINY_BUCKET)
    return names, configs, cells, compile_count() - c0
