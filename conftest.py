# Tests and benches must see the real (single) CPU device; only the
# dry-run module sets --xla_force_host_platform_device_count=512, and it
# does so before any jax import inside its own process.
import os

assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""), (
    "run pytest without the dry-run's XLA_FLAGS; smoke tests expect 1 device")
