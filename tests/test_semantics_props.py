"""Property tests: the paper's three correctness criteria (Section IV-A)
hold for the PB/PBC/PBCS state machine under arbitrary schedules.

Requires the optional ``hypothesis`` dependency; the deterministic
fallbacks in tests/test_semantics.py always run.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import PCSConfig, Scheme
from repro.core.semantics import EventKind, PersistentBuffer

from _semantics_driver import run_schedule

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]


@settings(max_examples=60, deadline=None)
@given(
    scheme=st.sampled_from(SCHEMES),
    n_pbe=st.integers(2, 8),
    ops=st.lists(st.tuples(st.sampled_from(["persist", "ack", "read"]),
                           st.integers(0, 5)), min_size=1, max_size=120),
    ack_order=st.lists(st.integers(0, 31), min_size=1, max_size=32),
)
def test_crash_consistency_and_write_order(scheme, n_pbe, ops, ack_order):
    pb, acked, _ = run_schedule(scheme, n_pbe, ops, ack_order)
    # crash at an arbitrary point, then recover: no acked version is lost
    pb.crash()
    pb.recover()
    for addr, ver in acked.items():
        rec = pb.pm.read(addr)
        assert rec is not None, f"acked addr {addr} lost"
        assert rec[0] >= ver, f"addr {addr}: pm={rec[0]} < acked={ver}"


@settings(max_examples=60, deadline=None)
@given(
    scheme=st.sampled_from([Scheme.PB, Scheme.PB_RF]),
    n_pbe=st.integers(2, 8),
    ops=st.lists(st.tuples(st.sampled_from(["persist", "ack", "read"]),
                           st.integers(0, 3)), min_size=1, max_size=120),
    ack_order=st.lists(st.integers(0, 31), min_size=1, max_size=32),
)
def test_write_read_order(scheme, n_pbe, ops, ack_order):
    """A read must observe the newest acked version (buffer or PM)."""
    pb, acked, reads = run_schedule(scheme, n_pbe, ops, ack_order)
    # replay: after the final state, reads of every acked address return
    # the newest acked payload from somewhere in the persistent domain
    for addr, ver in acked.items():
        data, ev = pb.read(addr)
        assert data is not None
        assert data == f"{addr}@" + data.split("@")[1]  # well-formed
        # version check: the entry served is >= newest acked
        assert ev.version >= ver or ev.kind == EventKind.READ_FROM_PM


@settings(max_examples=40, deadline=None)
@given(
    n_pbe=st.integers(4, 16),
    addrs=st.lists(st.integers(0, 30), min_size=1, max_size=200),
)
def test_rf_threshold_preset_invariant(n_pbe, addrs):
    """After any persist under PB_RF, the Dirty count never exceeds the
    drain threshold (the drain-down runs to the preset, Section V-D1)."""
    from repro.core.params import PBEState
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_pbe=n_pbe)
    pb = PersistentBuffer(cfg)
    for i, a in enumerate(addrs):
        evs = pb.persist(a, f"v{i}")
        dirty = sum(1 for e in pb.entries if e.state == PBEState.DIRTY)
        assert dirty <= max(cfg.threshold_count, cfg.preset_count + 1), (
            dirty, cfg.threshold_count)
        pb.check_invariants()


@settings(max_examples=40, deadline=None)
@given(
    scheme=st.sampled_from([Scheme.PB, Scheme.PB_RF]),
    ops=st.lists(st.tuples(st.booleans(), st.integers(0, 6)),
                 min_size=1, max_size=150),
)
def test_reads_never_return_stale_after_ack(scheme, ops):
    """Write-read order: a read after an acked persist returns that
    version's payload or newer, never an older one."""
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=4))
    newest = {}
    pending = []
    for is_persist, addr in ops:
        if is_persist:
            for e in pb.persist(addr, None):
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    newest[e.addr] = max(newest.get(e.addr, -1), e.version)
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
        elif pending:
            a, v = pending.pop(0)   # in-order acks (FIFO channel)
            for e in pb.pm_ack(a, v):
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    newest[e.addr] = max(newest.get(e.addr, -1), e.version)
        if addr in newest:
            _, ev = pb.read(addr)
            assert ev.version >= newest[addr], (
                scheme, addr, ev.version, newest[addr])
