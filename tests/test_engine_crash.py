"""Crash/recovery in the timed engine (Section V-D4).

Acceptance: a {7 workloads x 3 schemes x >= 4 crash points} grid lowers
to ONE XLA program — the crash time is just another stacked traced
config scalar.  The timed-regime tests then pin the durability
semantics under congestion (in-flight drains at the crash instant),
where the prompt-ack differential suite cannot reach: acked implies
durable, durable counts are monotone in the crash time, recovery cost
comes from the surviving Dirty/Drain entries, and the persistent-switch
schemes dominate the volatile baseline at every crash point.
"""
import numpy as np
import pytest

from conftest import TINY_BUCKET
from repro.core import Op, PCSConfig, Scheme, Trace
from repro.core.engine import compile_count, simulate, simulate_grid

SCHEMES = (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)


def test_workload_crash_grid_single_compile(paper_grid, tiny_traces):
    """The ISSUE acceptance grid: {7 workloads x 3 schemes x 4 crash
    points} through simulate_grid in one compilation."""
    names, _, base_cells, _ = paper_grid   # shared no-crash baseline
    traces = [tiny_traces[n] for n in names]
    t_max = max(row[1].runtime_ns for row in base_cells)
    crash_points = [f * t_max for f in (0.05, 0.25, 0.5, 0.75)]
    configs = [PCSConfig(scheme=s).with_crash(t)
               for s in SCHEMES for t in crash_points]
    c0 = compile_count()
    cells = simulate_grid(traces, configs, bucket=TINY_BUCKET)
    assert compile_count() - c0 == 1, (
        "crash-point sweep must reuse one XLA program")
    for i, name in enumerate(names):
        for j, cfg in enumerate(configs):
            r = cells[i][j]
            label = (name, cfg.scheme.name, cfg.crash_at_ns)
            # no acked version may be lost: acked => durable
            assert r.acked_persists <= r.durable_persists, label
            assert r.durable_persists <= r.persists, label
            if cfg.scheme == Scheme.NOPB:
                # volatile switch: nothing outlives the ack, recovery
                # has nothing to drain
                assert r.durable_persists == r.acked_persists, label
                assert r.recovery_entries == 0, label
                assert r.recovery_ns == 0.0, label
            else:
                # persistent switch: every persist committed into the
                # switch is durable; at most the one straddling the
                # crash instant (issued but not yet written) is lost
                assert r.durable_persists >= r.acked_persists, label
                assert r.recovery_ns >= 0.0, label
            assert r.runtime_ns <= cfg.crash_at_ns + 1e-6, label


def test_persisted_fraction_monotone_and_pb_dominates(paper_grid,
                                                      tiny_traces):
    """More time before the crash never loses persists, and the
    ack-at-switch schemes are durable-ahead of NoPB at every instant."""
    names, _, base_cells, _ = paper_grid
    tr = tiny_traces["radiosity"]
    t_end = base_cells[names.index("radiosity")][0].runtime_ns
    fracs = (0.1, 0.3, 0.5, 0.7, 0.9)
    configs = [PCSConfig(scheme=s).with_crash(f * t_end)
               for s in SCHEMES for f in fracs]
    cells = simulate_grid([tr], configs, bucket=TINY_BUCKET)[0]
    by_scheme = {s: cells[i * len(fracs):(i + 1) * len(fracs)]
                 for i, s in enumerate(SCHEMES)}
    for s in SCHEMES:
        durable = [r.durable_persists for r in by_scheme[s]]
        assert durable == sorted(durable), (s.name, durable)
    for j in range(len(fracs)):
        assert (by_scheme[Scheme.PB][j].durable_persists
                >= by_scheme[Scheme.NOPB][j].durable_persists), j
    # mid-run the persistent switch must be strictly ahead (the paper's
    # point: acks come back earlier, so more progress is durable)
    assert (by_scheme[Scheme.PB][2].durable_persists
            > by_scheme[Scheme.NOPB][2].durable_persists)


def _burst_trace(n_cores=16, per_core=24, n_addrs=64, gap=0.5):
    """Congested multi-core persist storm: the PB runs out of Empty
    entries, so victim drains fire and drains are in flight at any
    mid-run crash point (full-run victim_drains > 0 is asserted)."""
    rng = np.random.default_rng(17)
    ops = np.full((n_cores, per_core), int(Op.PERSIST), np.int32)
    addrs = rng.integers(0, n_addrs, (n_cores, per_core)).astype(np.int32)
    gaps = np.full((n_cores, per_core), gap, np.float32)
    return Trace(ops=ops, addrs=addrs, gaps=gaps,
                 lengths=np.full((n_cores,), per_core, np.int32),
                 name="burst")


@pytest.mark.parametrize("scheme", [Scheme.PB, Scheme.PB_RF])
def test_congested_crash_acked_never_lost(scheme):
    """Crash mid-drain under real congestion (victim evictions, slot
    reuse, in-flight PM writes lost with the power): every acked persist
    survives, and the durable-version vector accounts for exactly the
    committed persists — none lost to slot reuse, none invented."""
    tr = _burst_trace()
    n_addrs = 64
    cfg = PCSConfig(scheme=scheme, n_pbe=8, pm_banks=1)
    full = simulate(tr, cfg, bucket=128, track_addrs=n_addrs)
    assert full.victim_drains > 0, "trace must exercise the victim path"
    t_end = full.runtime_ns
    saw_recovery = False
    for f in np.linspace(0.05, 0.95, 19):
        r = simulate(tr, cfg.with_crash(f * t_end), bucket=128,
                     track_addrs=n_addrs)
        label = (scheme.name, round(float(f), 2))
        assert r.acked_persists <= r.durable_persists, label
        assert r.durable_persists <= r.persists, label
        dv = np.asarray(r.durable_ver)
        # per-address versions are dense over committed persists, every
        # committed version stays durable (PM + surviving PBEs), and
        # recovery never resurrects more than was issued
        assert dv.sum() == r.durable_persists, label
        saw_recovery |= r.recovery_entries > 0
    assert saw_recovery, "no crash point caught in-flight/dirty entries"


def test_crash_straddling_persist_not_double_counted():
    """A persist issued before but written after the crash commits
    nothing: the overwritten-slot version survives via its Drain entry
    and the newcomer is neither acked, durable, nor versioned."""
    tr = _burst_trace()
    cfg = PCSConfig(scheme=Scheme.PB, n_pbe=4, pm_banks=1)
    t_end = simulate(tr, cfg, bucket=128).runtime_ns
    for f in np.linspace(0.1, 0.9, 9):
        r = simulate(tr, cfg.with_crash(f * t_end), bucket=128,
                     track_addrs=64)
        dv = np.asarray(r.durable_ver)
        assert dv.sum() == r.durable_persists, f
        assert r.acked_persists <= r.durable_persists, f


def test_mid_chain_crash_acked_persist_survives_from_hop1():
    """Mid-chain crash acceptance (pooling topologies): a persist acked
    at hop 1 whose hop-2 propagation lands only after the power loss is
    still durable — hop 1's PB cells hold it in Drain (its downstream
    ack is lost with the power), so recovery re-drains it from hop 1."""
    # one persist, then a crash falling inside the hop-1 -> hop-2 hop
    # window: after the hop-1 ack (~2*(link+pipe)+service) but before
    # the inter-switch commit lands at hop 2
    tr = Trace(ops=np.array([[int(Op.PERSIST)]], np.int32),
               addrs=np.array([[0]], np.int32),
               gaps=np.zeros((1, 1), np.float32),
               lengths=np.array([1], np.int32), name="one")
    cfg = PCSConfig(scheme=Scheme.PB, n_switches=2, n_pbe=4)
    full = simulate(tr, cfg, bucket=64, track_addrs=1)
    assert full.persists == 1 and full.acked_persists == 1
    ack_ns = full.persist_lat_ns          # hop-1 round trip
    hop_ns = cfg.latency.hop_ns()
    # the forward leaves hop 1 at the entry-write instant (~ack minus
    # the return link) and needs a full hop + hop-2 PBC service to
    # commit: a crash shortly after the ack falls mid-wire
    crash = ack_ns + 0.25 * hop_ns
    r = simulate(tr, cfg.with_crash(crash), bucket=64, track_addrs=1)
    assert r.acked_persists == 1, "persist must be acked before the crash"
    assert r.durable_persists == 1, "acked persist lost mid-chain"
    assert int(np.asarray(r.durable_ver)[0]) == 1
    # durable FROM HOP 1: the copy survives in hop 1's PB (Drain, ack
    # pending), not at hop 2 (commit landed post-crash) and not at PM
    assert r.hop_recovery is not None
    assert list(r.hop_recovery) == [1, 0], list(r.hop_recovery)
    assert r.recovery_entries == 1
    # and once the hop-2 commit beats the crash, the surviving copy
    # moves one hop deeper (hop 1's entry freed by the downstream ack)
    r2 = simulate(tr, cfg.with_crash(full.runtime_ns + 5e6), bucket=64,
                  track_addrs=1)
    assert r2.durable_persists == 1
    assert list(r2.hop_recovery) == [0, 0], list(r2.hop_recovery)


def test_crash_at_zero_and_after_end(tiny_traces):
    tr = tiny_traces["raytrace"]
    r0 = simulate(tr, PCSConfig(scheme=Scheme.PB_RF).with_crash(0.0),
                  bucket=TINY_BUCKET)
    assert r0.persists == 0 and r0.durable_persists == 0
    assert r0.runtime_ns == 0.0 and r0.recovery_entries == 0
    r_inf = simulate(tr, PCSConfig(scheme=Scheme.PB_RF), bucket=TINY_BUCKET)
    assert r_inf.durable_persists == r_inf.persists == r_inf.acked_persists
    assert r_inf.persists > 0


def test_no_crash_results_unchanged_by_crash_fields(tiny_traces):
    """crash_at_ns=inf is the identity: same results as before the crash
    model existed (drift guard for the figure pipeline)."""
    tr = tiny_traces["lu_cont"]
    a = simulate(tr, PCSConfig(scheme=Scheme.PB_RF), bucket=TINY_BUCKET)
    b = simulate(tr, PCSConfig(scheme=Scheme.PB_RF).with_crash(1e27),
                 bucket=TINY_BUCKET)
    for f in ("runtime_ns", "persists", "pm_writes", "coalesces",
              "read_hits", "stall_ns"):
        assert getattr(a, f) == pytest.approx(getattr(b, f), rel=1e-12), f
