"""Macro-stepping unit tests: the run planner, the guard fallback, and
the commit paths (DESIGN.md "Macro-stepping & state packing").

The crash differential pins macro-vs-plain bit-exactness over fuzzed
matrices (tests/test_crash_differential.py); this file covers the
mechanism itself — ``plan_runs`` eligibility rules, guard-failure
fallback to the slot-at-a-time handlers, dead-run collapse, and the
``macro_ops`` telemetry behind ``last_macro_hit_rate``.
"""
import numpy as np
import pytest

from repro.core import Op, PCSConfig, Scheme, Trace
from repro.core.engine import last_macro_hit_rate, simulate
from repro.core.params import MACRO_KMAX
from repro.core.traces import plan_runs

BUCKET = 128


def _trace(ops, addrs, gap=2000.0):
    ops = np.asarray([ops], np.int32)
    return Trace(ops=ops,
                 addrs=np.asarray([addrs], np.int32),
                 gaps=np.full(ops.shape, gap, np.float32),
                 lengths=np.asarray([ops.shape[1]], np.int32),
                 name="macro_probe")


def _assert_equal_results(a, b, label=""):
    for f in a.__dataclass_fields__:
        x, y = getattr(a, f), getattr(b, f)
        if isinstance(x, np.ndarray):
            assert y is not None and np.array_equal(x, y), (label, f)
        else:
            both_nan = (isinstance(x, float) and isinstance(y, float)
                        and np.isnan(x) and np.isnan(y))
            assert x == y or both_nan, (label, f, x, y)


# ------------------------------------------------------------ plan_runs
def test_plan_runs_eligibility():
    """Only PM_READ/PERSIST slots with non-negative gaps start runs;
    run length counts the homogeneous suffix, capped at MACRO_KMAX."""
    ops = np.asarray([[int(Op.PM_READ)] * 12], np.int32)
    addrs = np.arange(12, dtype=np.int32)[None, :]
    gaps = np.full((1, 12), 10.0, np.float32)
    mlen = plan_runs(ops, addrs, gaps)
    assert mlen[0, 0] == MACRO_KMAX           # capped
    assert mlen[0, 11] == 1                   # nothing after it
    assert mlen[0, 12 - MACRO_KMAX] == MACRO_KMAX

    # a COMPUTE op breaks the run and is itself ineligible
    ops2 = ops.copy()
    ops2[0, 5] = int(Op.COMPUTE)
    mlen2 = plan_runs(ops2, addrs, gaps)
    assert mlen2[0, 0] == 5
    assert mlen2[0, 5] == 1
    # a negative gap (impossible issue order) is likewise ineligible
    gaps3 = gaps.copy()
    gaps3[0, 3] = -1.0
    assert plan_runs(ops, addrs, gaps3)[0, 0] == 3


def test_plan_runs_same_addr_persist_pairs_excluded():
    """A window holding two ops on one address where either is a PERSIST
    is statically excluded (coalesce/read-forwarding territory); pure
    read-read repeats are fine."""
    P, R = int(Op.PERSIST), int(Op.PM_READ)
    gaps = np.full((1, 4), 10.0, np.float32)
    # persist a, read a -> pair blocked at the persist
    mlen = plan_runs(np.asarray([[P, R, R, R]], np.int32),
                     np.asarray([[7, 7, 8, 9]], np.int32), gaps)
    assert mlen[0, 0] == 1 and mlen[0, 1] == 3
    # read a, read a -> no persist involved, window OK
    mlen = plan_runs(np.asarray([[R, R, R, R]], np.int32),
                     np.asarray([[7, 7, 8, 9]], np.int32), gaps)
    assert mlen[0, 0] == 4
    # persist a ... persist a two apart -> blocked at that distance
    mlen = plan_runs(np.asarray([[P, P, P, P]], np.int32),
                     np.asarray([[7, 8, 7, 9]], np.int32), gaps)
    assert mlen[0, 0] == 2


# ----------------------------------------------------- guard fallback
@pytest.mark.parametrize("scheme", [Scheme.PB, Scheme.PB_RF])
def test_guard_failure_falls_back_bit_exact(scheme):
    """A statically eligible window whose *runtime* guard fails (a PB
    read hit mid-window) must fall back to the slot-at-a-time handlers
    and still match the macro-disabled engine exactly."""
    P, R = int(Op.PERSIST), int(Op.PM_READ)
    # persist 5 primes the PB; the later [read 5, read 6] window is
    # statically eligible but read 5 hits the buffered entry -> abort
    # (tight gaps: the reads issue while the entry is still live, before
    # lazy-free could turn the PB drain into a miss)
    tr = _trace([P, R, R], [5, 5, 6], gap=10.0)
    cfg = PCSConfig(scheme=scheme, n_pbe=4)
    r_macro = simulate(tr, cfg, bucket=BUCKET, track_addrs=8)
    hit = last_macro_hit_rate()
    r_plain = simulate(tr, cfg, bucket=BUCKET, track_addrs=8, macro=False)
    _assert_equal_results(r_macro, r_plain, label=scheme.name)
    # the aborted window fell back: no slot of this trace ran as a macro
    # step (the only eligible window was the one that hit)
    assert hit == 0.0, hit


def test_macro_commit_pure_miss_window():
    """Distinct-address read windows commit: hit rate > 0 and results
    stay identical to the macro-disabled engine."""
    R = int(Op.PM_READ)
    tr = _trace([R] * 10, list(range(10)))
    cfg = PCSConfig(scheme=Scheme.PB, n_pbe=4)
    r_macro = simulate(tr, cfg, bucket=BUCKET)
    hit = last_macro_hit_rate()
    r_plain = simulate(tr, cfg, bucket=BUCKET, macro=False)
    _assert_equal_results(r_macro, r_plain)
    assert hit > 0.5, hit


def test_macro_disabled_reports_zero_hit_rate():
    R = int(Op.PM_READ)
    tr = _trace([R] * 6, list(range(6)))
    simulate(tr, PCSConfig(scheme=Scheme.PB), bucket=BUCKET, macro=False)
    assert last_macro_hit_rate() == 0.0


def test_dead_run_collapse_after_crash():
    """Post-crash streams collapse MACRO_KMAX slots at a time — even for
    op mixes (COMPUTE, coalescing persists) the live path never takes —
    and the crashed results match the macro-disabled engine exactly."""
    P, C = int(Op.PERSIST), int(Op.COMPUTE)
    # same-address persists + computes: statically ineligible live runs
    tr = _trace([P, C] * 15, [3, 0] * 15, gap=1000.0)
    cfg = PCSConfig(scheme=Scheme.PB, n_pbe=4).with_crash(1500.0)
    r_macro = simulate(tr, cfg, bucket=BUCKET, track_addrs=8)
    hit = last_macro_hit_rate()
    r_plain = simulate(tr, cfg, bucket=BUCKET, track_addrs=8, macro=False)
    _assert_equal_results(r_macro, r_plain)
    assert hit > 0.5, hit
