"""Runtime substrate: failure detection, elastic remesh, stragglers,
optimizer and data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLMDataset
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm, topk_compress_grads)
from repro.runtime import (FailureDetector, NodeStatus, StragglerMitigator,
                           plan_mesh)


def test_failure_detector_states():
    t = [0.0]
    det = FailureDetector(["a", "b"], suspect_after_s=1.0, dead_after_s=3.0,
                          clock=lambda: t[0])
    t[0] = 1.5
    det.heartbeat("a")
    t[0] = 2.0
    st = det.sweep()
    assert st["a"] == NodeStatus.HEALTHY
    assert st["b"] == NodeStatus.SUSPECT
    t[0] = 4.0
    st = det.sweep()
    assert st["a"] == NodeStatus.SUSPECT
    assert st["b"] == NodeStatus.DEAD
    assert det.alive() == ["a"]


def test_elastic_plan_shrinks_data_axis():
    plan = plan_mesh(256, model_parallel=16)
    assert plan.shape == (16, 16) and plan.grad_accum == 1
    plan = plan_mesh(255, model_parallel=16)
    assert plan.shape == (15, 16) and plan.grad_accum == 2
    plan = plan_mesh(511, model_parallel=16, pods=2)
    assert plan.shape == (2, 15, 16)
    assert plan_mesh(7, model_parallel=16) is None


def test_straggler_flags_and_catchup():
    m = StragglerMitigator(window=16, deadline_factor=2.0)
    for _ in range(10):
        assert not m.observe(1.0)
    assert m.observe(5.0)
    assert m.take_catchup() == 1
    assert m.take_catchup() == 0


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=100, schedule="const")
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(cfg, params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                      warmup_steps=1, schedule="const")
    params = {"w": jnp.zeros(4)}
    state = adamw_init(cfg, params)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in (1, 10, 100)]
    assert lrs[0] < lrs[1]
    assert lrs[2] < 1e-6


def test_topk_compression_error_feedback():
    g = {"w": jnp.asarray([1.0, 0.1, 0.01, 0.001])}
    comp, err = topk_compress_grads(g, None, ratio=0.25)
    assert float(jnp.sum(comp["w"] != 0)) == 1
    # the residual is carried and eventually transmitted
    comp2, err2 = topk_compress_grads(
        jax.tree.map(jnp.zeros_like, g), err, ratio=0.25)
    assert float(comp2["w"][1]) > 0.0


def test_data_pipeline_deterministic_resume():
    d1 = SyntheticLMDataset(1000, 16, 4, seed=7)
    b0 = d1.next_batch()
    st = d1.state()
    b1 = d1.next_batch()
    d2 = SyntheticLMDataset(1000, 16, 4, seed=7)
    d2.restore(st)
    b1b = d2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_sharding_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_spec
    mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    class K:
        def __init__(self, key):
            self.key = key

    # ffn up: (d, f) -> (data, model)
    spec = param_spec(mesh, [K("blocks"), K("0"), K("ffn"), K("up"), K("w")],
                      Leaf((26, 2304, 9216)))
    assert spec == P(None, "data", "model")
    # wo: (h*hd, d) -> (model, data)
    spec = param_spec(mesh, [K("blocks"), K("0"), K("attn"), K("wo"), K("w")],
                      Leaf((26, 2048, 2304)))
    assert spec == P(None, "model", "data")
    # non-divisible vocab falls back to d_model sharding
    spec = param_spec(mesh, [K("embed"), K("table")], Leaf((256206, 1024)))
    assert spec == P(None, "model")
    spec = param_spec(mesh, [K("embed"), K("table")], Leaf((256000, 2304)))
    assert spec == P("model", "data")
    # norms replicate (beyond the stacked dim)
    spec = param_spec(mesh, [K("blocks"), K("0"), K("ln1"), K("scale")],
                      Leaf((26, 2304)))
    assert spec == P(None, None)
