"""LatencyProfile path helpers and PCSConfig validation.

The one-way path helpers compose the CPU->switch->...->PM chain; the
engine lowers them into every persist/read/drain path, so their algebra
(non-negativity, monotonicity in switch depth, and the split-path
composition identity) is load-bearing for every figure.
"""
import math

import pytest

from repro.core import LatencyProfile, PCSConfig, Scheme

DEPTHS = range(0, 9)
PROFILES = [
    LatencyProfile(),
    LatencyProfile(link_ns=37.5, switch_pipe_ns=12.25, cpu_link_ns=80.0),
    LatencyProfile(link_ns=0.0, switch_pipe_ns=0.0),   # degenerate chain
]


@pytest.mark.parametrize("lat", PROFILES)
def test_path_helpers_non_negative(lat):
    """All three helpers are total functions of the depth, 0 included
    (the old ``oneway_sw1_pm(0)`` evaluated to MINUS switch_pipe_ns and
    had to be special-cased out of the engine lowering)."""
    for n in DEPTHS:
        assert lat.oneway_cpu_pm(n) >= 0.0, n
        assert lat.oneway_cpu_sw1(n) >= 0.0, n
        assert lat.oneway_sw1_pm(n) >= 0.0, n
    assert lat.oneway_cpu_sw1() >= 0.0


@pytest.mark.parametrize("lat", PROFILES[:2])
def test_path_latency_monotone_in_switch_depth(lat):
    """Each extra switch adds link + pipe time: strictly monotone for
    positive segment latencies, on both the full and the drain path."""
    full = [lat.oneway_cpu_pm(n) for n in DEPTHS if n >= 1]
    drain = [lat.oneway_sw1_pm(n) for n in DEPTHS if n >= 1]
    assert all(b > a for a, b in zip(full, full[1:]))
    assert all(b > a for a, b in zip(drain, drain[1:]))


@pytest.mark.parametrize("lat", PROFILES)
def test_path_composition_identity(lat):
    """CPU->sw1 plus sw1->PM must equal the end-to-end CPU->PM path for
    every chain with at least one switch (the PB ack point splits the
    persist path exactly there)."""
    for n in range(1, 9):
        whole = lat.oneway_cpu_pm(n)
        split = lat.oneway_cpu_sw1() + lat.oneway_sw1_pm(n)
        assert split == pytest.approx(whole, rel=1e-12, abs=1e-12), n


@pytest.mark.parametrize("lat", PROFILES)
def test_path_composition_identity_total_at_depth_zero(lat):
    """The depth-aware helper forms extend the identity to n == 0
    (direct attach: the "first hop" degenerates to the CPU link and the
    drain path to nothing) — the engine lowering needs no depth
    special-casing (the old state.py ow_cpu_sw1/ow_sw1_pm branches)."""
    for n in range(0, 9):
        whole = lat.oneway_cpu_pm(n)
        split = lat.oneway_cpu_sw1(n) + lat.oneway_sw1_pm(n)
        assert split == pytest.approx(whole, rel=1e-12, abs=1e-12), n
    assert lat.oneway_sw1_pm(0) == 0.0
    assert lat.oneway_cpu_sw1(0) == lat.cpu_link_ns


@pytest.mark.parametrize("lat", PROFILES)
def test_hop_segment_decomposes_drain_path(lat):
    """``hop_ns`` (one inter-switch segment) decomposes the drain path:
    sw1 -> PM through n switches = (n-1) hops plus the final link —
    the identity the chain's forward/PM-landing latencies are built on."""
    for n in range(1, 9):
        assert lat.oneway_sw1_pm(n) == pytest.approx(
            (n - 1) * lat.hop_ns() + lat.link_ns, rel=1e-12, abs=1e-12), n


# ---------------------------------------------------------------------------
# PCSConfig validation
# ---------------------------------------------------------------------------

def test_pb_scheme_requires_a_switch():
    """A persistent buffer with no switch for it to live in must be
    rejected, not silently simulated with a free (0 ns) drain path."""
    for scheme in (Scheme.PB, Scheme.PB_RF):
        with pytest.raises(ValueError, match="n_switches"):
            PCSConfig(scheme=scheme, n_switches=0)
    # the volatile baseline legitimately supports direct-attached PM
    cfg = PCSConfig(scheme=Scheme.NOPB, n_switches=0)
    assert cfg.n_switches == 0


def test_nopb_zero_switches_still_simulates():
    import numpy as np

    from repro.core import Op, Trace, simulate

    ops = np.array([[int(Op.PERSIST), int(Op.PM_READ)] * 4], np.int32)
    addrs = np.arange(8, dtype=np.int32)[None, :]
    tr = Trace(ops=ops, addrs=addrs,
               gaps=np.full((1, 8), 2000.0, np.float32),
               lengths=np.array([8], np.int32), name="direct")
    lat = LatencyProfile()
    r = simulate(tr, PCSConfig(scheme=Scheme.NOPB, n_switches=0,
                               latency=lat), bucket=64)
    # uncongested direct-attach round trip: 2x cpu_link + device latency
    assert r.persist_lat_ns == pytest.approx(
        2 * lat.cpu_link_ns + lat.nvm_write_ns, abs=1.0)


def test_tenant_count_validation():
    with pytest.raises(ValueError, match="n_tenants"):
        PCSConfig(n_tenants=0)
    with pytest.raises(ValueError, match="n_tenants"):
        PCSConfig(n_tenants=9, n_cores=8)
    assert PCSConfig(n_tenants=8, n_cores=8).n_tenants == 8


def test_empty_mean_is_nan_not_zero():
    """A cell with no persists/reads has no mean latency: NaN, not a
    0.0 that plots as infinitely fast (fig_recovery crash_at=0)."""
    import numpy as np

    from repro.core.engine.state import N_STATS, result_from_stats

    r = result_from_stats(0.0, np.zeros((N_STATS,)), crash_at_ns=0.0)
    assert math.isnan(r.persist_lat_ns)
    assert math.isnan(r.read_lat_ns)
    assert r.persists == 0 and r.pm_reads == 0
