"""Serving-SLO telemetry: arrival processes, the per-persist latency
histogram, percentile reconstruction and the latency-target drain
policy.

The histogram rides in the per-tenant ``MachineState.stats`` rows
(``S_LAT_HIST0 .. S_LAT_HIST0 + N_LAT_BINS``), accumulated with the
same expression at the persist handler and the macro fast path; these
tests pin its mass accounting, the bin mapping, the percentile/mean
reconstruction bounds, the open-loop arrival generators, and the
``DrainPolicy(latency_target_ns=...)`` lowering (a never-reached
target must be indistinguishable from no target; a tiny one must
visibly tighten drain-down).
"""
import math

import numpy as np
import pytest

from repro.core import (BurstyArrivals, DiurnalArrivals, DrainPolicy,
                        PBPolicy, PCSConfig, PoissonArrivals, Scheme,
                        apply_arrivals, make_offered_load_trace, make_trace)
from repro.core.engine import compile_count, simulate, simulate_grid
from repro.core.engine.state import (LAT_HIST_MIN_NS, LAT_HIST_RATIO,
                                     N_LAT_BINS, lat_bin, lat_hist_edges,
                                     lat_hist_mean, lat_hist_percentile)

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]


# ===========================================================================
# Histogram bin layout and reconstruction helpers
# ===========================================================================

def test_lat_bin_layout():
    """Bin 0 is the underflow bin; bin k >= 1 holds
    [MIN * r^(k-1), MIN * r^k); the last bin is open."""
    r = LAT_HIST_RATIO
    assert int(lat_bin(0.0)) == 0
    assert int(lat_bin(LAT_HIST_MIN_NS - 1.0)) == 0
    assert int(lat_bin(LAT_HIST_MIN_NS)) == 1
    assert int(lat_bin(LAT_HIST_MIN_NS * r * 0.999)) == 1
    assert int(lat_bin(LAT_HIST_MIN_NS * r * 1.001)) == 2
    assert int(lat_bin(1e12)) == N_LAT_BINS - 1
    edges = lat_hist_edges()
    assert len(edges) == N_LAT_BINS - 1
    # every finite edge maps to the bin it opens
    for k, e in enumerate(edges):
        assert int(lat_bin(e * 1.0001)) == k + 1, (k, e)
    # the span covers sub-us service latencies through ms-scale stalls
    assert edges[0] == LAT_HIST_MIN_NS
    assert edges[-1] > 1e6


def test_percentiles_from_hist():
    hist = np.zeros(N_LAT_BINS)
    # empty histogram: percentiles and mean are NaN, never 0.0
    assert math.isnan(lat_hist_percentile(hist, 0.50))
    assert math.isnan(lat_hist_mean(hist))
    # all mass in one bin: every percentile lands inside that bin and
    # the mean is its geometric midpoint
    edges = lat_hist_edges()
    hist[5] = 100.0
    lo, hi = edges[4], edges[5]
    for q in (0.01, 0.50, 0.99):
        p = lat_hist_percentile(hist, q)
        assert lo <= p <= hi, (q, p, lo, hi)
    assert lo <= lat_hist_mean(hist) <= hi
    # two-bin split: the median sits in the upper bin once the lower
    # holds less than half the mass, and percentiles are monotone in q
    hist[:] = 0.0
    hist[3], hist[10] = 40.0, 60.0
    ps = [lat_hist_percentile(hist, q) for q in (0.10, 0.50, 0.95)]
    assert ps == sorted(ps)
    assert ps[0] <= edges[3]
    assert edges[9] <= ps[1] <= edges[10]


# ===========================================================================
# Engine accumulation: mass, mean agreement, percentile surface
# ===========================================================================

def _small(workload="raytrace", budget=300):
    return make_trace(workload, n_cores=4, persist_budget=budget)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_hist_mass_and_mean_agree(scheme):
    """Histogram mass equals the persist count, and the histogram-
    reconstructed mean matches S_PERSIST_SUM / S_PERSIST_CNT within the
    sqrt(2) bin resolution (geometric mids are within r^(1/2) of any
    point in their bin)."""
    res = simulate(_small(), PCSConfig(scheme=scheme, n_cores=4))
    assert res.lat_hist is not None
    assert int(res.lat_hist.sum()) == res.persists > 0
    approx = lat_hist_mean(res.lat_hist)
    exact = res.persist_lat_ns
    tol = math.sqrt(LAT_HIST_RATIO)          # one half-bin, ~19%
    assert exact / tol <= approx <= exact * tol, (approx, exact)
    # the percentile surface is monotone and brackets the mean's bin
    p50, p95, p99 = (res.persist_lat_p50, res.persist_lat_p95,
                     res.persist_lat_p99)
    assert 0.0 < p50 <= p95 <= p99, (p50, p95, p99)


def test_tenant_hist_rows_sum_to_total():
    trace = make_trace("radiosity", n_cores=4, persist_budget=300)
    res = simulate(trace, PCSConfig(scheme=Scheme.PB_RF, n_cores=4,
                                    n_tenants=2))
    rows = res.tenant_results()
    assert len(rows) == 2
    per_tenant = np.stack([r.lat_hist for r in rows])
    assert np.array_equal(per_tenant.sum(axis=0), res.lat_hist)
    for r in rows:
        assert int(r.lat_hist.sum()) == r.persists


# ===========================================================================
# Open-loop arrival processes
# ===========================================================================

def test_arrivals_retime_only_gaps():
    base = _small()
    loaded = apply_arrivals(base, 2.0, seed=3)       # bare rate -> Poisson
    assert np.array_equal(base.ops, loaded.ops)
    assert np.array_equal(base.addrs, loaded.addrs)
    assert np.array_equal(base.lengths, loaded.lengths)
    assert not np.array_equal(base.gaps, loaded.gaps)
    assert "poisson2" in loaded.name


def test_arrivals_deterministic_and_seeded():
    base = _small()
    a = apply_arrivals(base, PoissonArrivals(4.0), seed=1)
    b = apply_arrivals(base, PoissonArrivals(4.0), seed=1)
    c = apply_arrivals(base, PoissonArrivals(4.0), seed=2)
    assert np.array_equal(a.gaps, b.gaps)
    assert not np.array_equal(a.gaps, c.gaps)


@pytest.mark.parametrize("proc,tol", [
    (PoissonArrivals(2.0), 0.10),
    (BurstyArrivals(2.0, burst=8.0, on_fraction=0.25), 0.25),
    (DiurnalArrivals(2.0, amplitude=0.5), 0.25),
])
def test_arrival_rate_accuracy(proc, tol):
    """Long-run offered rate (Mops/s = 1000 / mean-gap-ns over the
    nominal clock) matches the process's time-average rate."""
    rng = np.random.default_rng(7)
    gaps = proc.sample_gaps(20_000, rng)
    assert (gaps > 0).all()
    got = 1000.0 * len(gaps) / gaps.sum()
    assert abs(got - proc.rate_mops) <= tol * proc.rate_mops, got


def test_bursty_rates_straddle_the_mean():
    proc = BurstyArrivals(2.0, burst=8.0, on_fraction=0.25)
    assert proc.rate_at(0.0) > 2.0                  # on-phase
    assert proc.rate_at(proc.period_ns * 0.9) < 2.0  # off-phase


def test_per_tenant_arrival_processes():
    trace = make_trace("raytrace", n_cores=4, persist_budget=400)
    loaded = apply_arrivals(trace, [PoissonArrivals(0.5),
                                    PoissonArrivals(8.0)],
                            seed=0, n_tenants=2)
    # tenant 0 = cores 0..1 (slow), tenant 1 = cores 2..3 (fast)
    def mean_gap(c):
        n = int(loaded.lengths[c])
        return float(loaded.gaps[c, :n].mean())
    assert mean_gap(0) > 4 * mean_gap(2)
    with pytest.raises(ValueError):
        apply_arrivals(trace, [PoissonArrivals(1.0)] * 3, n_tenants=2)


def test_arrival_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(0.0)
    with pytest.raises(ValueError):
        BurstyArrivals(1.0, burst=0.5)
    with pytest.raises(ValueError):
        DiurnalArrivals(1.0, amplitude=1.5)


# ===========================================================================
# Offered-load sweep: saturation raises the tail, one compiled program
# ===========================================================================

def test_offered_load_tail_rises_one_compile():
    """Enough cores behind one switch (32) saturate the shared PBC/PM
    at high offered load: the retention-heavy PB_RF scheme's P99
    explodes while the op stream stays identical."""
    rates = (0.25, 32.0)
    traces = [make_offered_load_trace("raytrace", r, n_cores=32,
                                      persist_budget=1600)
              for r in rates]
    configs = [PCSConfig(scheme=Scheme.PB_RF, n_cores=32)]
    c0 = compile_count()
    cells = simulate_grid(traces, configs, bucket=512)
    assert compile_count() - c0 == 1, (
        "the offered-load axis is a trace axis; the sweep must stay "
        "one XLA program")
    lo, hi = cells[0][0], cells[1][0]
    assert lo.persists == hi.persists            # same op stream
    # saturated arrivals queue at the shared PBC/PM: the tail explodes
    assert hi.persist_lat_p99 > 1.5 * lo.persist_lat_p99, (
        lo.persist_lat_p99, hi.persist_lat_p99)
    assert lo.persist_lat_p50 <= lo.persist_lat_p95 <= lo.persist_lat_p99


# ===========================================================================
# Latency-target drain policy
# ===========================================================================

def test_latency_target_validation():
    with pytest.raises(ValueError):
        DrainPolicy(latency_target_ns=0.0)
    with pytest.raises(ValueError):
        DrainPolicy(latency_target_ns=-100.0)
    with pytest.raises(ValueError):
        DrainPolicy(latency_tol=1.0)


def test_huge_target_is_identity():
    """A target no ack ever exceeds must lower bit-exactly to the
    default policy: ``tight`` never fires, S_SLO_OVER stays 0."""
    trace = _small()
    base = simulate(trace, PCSConfig(scheme=Scheme.PB_RF, n_cores=4))
    slo = simulate(trace, PCSConfig(
        scheme=Scheme.PB_RF, n_cores=4,
        policy=PBPolicy(drain=DrainPolicy(latency_target_ns=1e12))))
    assert slo.slo_violations == 0
    for f in base.__dataclass_fields__:
        x, y = getattr(base, f), getattr(slo, f)
        if isinstance(x, np.ndarray):
            assert np.array_equal(x, y), f
        else:
            assert x == y or (isinstance(x, float) and np.isnan(x)
                              and np.isnan(y)), (f, x, y)


def test_tiny_target_tightens_drains():
    """An unreachable 1 ns target marks every persist over-SLO, so
    drain-down runs tight (threshold 1, preset 0) from the first
    persist — observably more PM write traffic / fewer coalesces than
    the default lazy threshold on a coalescing-friendly workload."""
    trace = make_trace("radiosity", n_cores=4, persist_budget=400)
    base = simulate(trace, PCSConfig(scheme=Scheme.PB_RF, n_cores=4))
    slo = simulate(trace, PCSConfig(
        scheme=Scheme.PB_RF, n_cores=4,
        policy=PBPolicy(drain=DrainPolicy(latency_target_ns=1.0))))
    assert slo.slo_violations == slo.persists > 0
    assert base.slo_violations == 0
    assert (slo.pm_writes, slo.coalesces) != (base.pm_writes,
                                              base.coalesces), (
        "tight drain-down changed nothing observable")
    assert slo.pm_writes >= base.pm_writes
