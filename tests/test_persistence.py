"""Layer-B persistence tier: PCS semantics over checkpoint shards."""
import threading
import time

import numpy as np
import pytest

from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)


def mk(tmp_path, scheme, cap_mb=64, sync=True, delay=0.0):
    buf = HostBufferTier(capacity_bytes=cap_mb << 20)
    store = DurableStore(str(tmp_path / "store"), write_delay_s=delay)
    return PCSCheckpointManager(buf, store, scheme=scheme, sync_drain=sync)


@pytest.mark.parametrize("scheme", list(PersistScheme))
def test_persist_restore_roundtrip(tmp_path, scheme):
    mgr = mk(tmp_path, scheme)
    arr = np.arange(100, dtype=np.float32)
    mgr.persist("w", 1, arr)
    got = mgr.restore("w")
    assert got is not None and got[0] == 1
    np.testing.assert_array_equal(got[1], arr)
    mgr.close()


def test_write_order_stale_rejected(tmp_path):
    store = DurableStore(str(tmp_path / "s"))
    assert store.write("x", 5, b"new")
    assert not store.write("x", 3, b"old")     # stale must not overwrite
    assert store.read("x") == (5, b"new")
    assert store.stale_rejected == 1


def test_rf_read_forwarding(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    mgr.persist("w", 1, np.ones(4))
    got = mgr.restore("w")
    assert got[0] == 1
    assert mgr.stats["restore_forwarded"] >= 1
    mgr.close()


def test_rf_write_coalescing(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    for v in range(1, 6):
        mgr.persist("w", v, np.full(4, v))
    assert mgr.stats["coalesces"] >= 3         # undrained olds superseded
    mgr.drain_all()
    assert mgr.store.read("w")[0] == 5
    mgr.close()


def test_pb_drains_every_version(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB, sync=True)
    for v in range(1, 4):
        mgr.persist("w", v, np.full(4, v))
    assert mgr.stats["coalesces"] == 0
    assert mgr.store.writes_applied == 3
    mgr.close()


def test_crash_recovery_drains_survivors(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    mgr.persist("a", 1, np.ones(8))
    mgr.persist("b", 1, np.zeros(8))
    mgr.crash()                                 # drainer dies, queue lost
    assert mgr.store.read("a") is None or mgr.store.read("b") is None \
        or True  # drains may or may not have landed — recovery must fix it
    n = mgr.recover()
    assert n >= 0
    for s in ("a", "b"):
        assert mgr.store.read(s) is not None, f"{s} lost after recovery"


@pytest.mark.parametrize("scheme",
                         [PersistScheme.PB, PersistScheme.PB_RF])
def test_scheduled_crash_window_is_deterministic(tmp_path, scheme):
    """schedule_crash(n): exactly n persists ack, later ones are dropped
    (power off), and recovery preserves precisely the acked prefix —
    the checkpoint-tier mirror of the engine's crash_at_ns."""
    mgr = mk(tmp_path, scheme, sync=False)
    mgr.schedule_crash(3)
    for v in range(1, 7):
        mgr.persist(f"s{v}", v, np.full(8, v))
    assert mgr.stats["acks"] == 3
    assert mgr.stats["lost_after_crash"] == 3
    n = mgr.recover()
    assert n >= 0
    for v in range(1, 4):          # acked before the crash: durable
        rec = mgr.store.read(f"s{v}")
        assert rec is not None and rec[0] == v, f"acked s{v} lost"
    for v in range(4, 7):          # never reached the switch: gone
        assert mgr.store.read(f"s{v}") is None, f"s{v} resurrected"
        assert mgr.buffer.newest(f"s{v}") is None
    # recover() restarts the drainer: the manager is usable again
    mgr.persist("post", 9, np.ones(4))
    mgr.drain_all()
    assert mgr.store.read("post")[0] == 9
    mgr.close()


def test_quota_schedule_steps_at_persist_index(tmp_path):
    """A ``tenant_quota`` Schedule honoured host-side: boundaries are
    read as PERSIST INDICES (the tier's logical clock), so the quota
    step lands at an exact acked-persist count — the checkpoint-tier
    mirror of the engine's issue-clock epoch gate, deterministic
    despite the asynchronous drainer (drain initiation is synchronous
    under the lock)."""
    from repro.core.params import AllocPolicy, PBPolicy, Schedule
    from repro.persistence.manager import ShardState

    buf = HostBufferTier(capacity_bytes=64 << 20)
    store = DurableStore(str(tmp_path / "store"))
    pol = PBPolicy(alloc=AllocPolicy(
        tenant_quota=Schedule((4.0,), ((3,), (1,)))))
    mgr = PCSCheckpointManager(buf, store, scheme=PersistScheme.PB_RF,
                               policy=pol, sync_drain=False)
    # epoch 0 (quota 3): distinct shards (no coalescing), tiny payloads
    # (the byte threshold never trips) — only the quota can force drains
    for v in range(1, 5):
        mgr.persist(f"s{v}", v, np.full(8, v))
    assert mgr._epoch == 0
    # persist #3 pushed tenant 0 to 4 dirty > quota 3: exactly one
    # quota drain (the LRU entry) fired in epoch 0
    assert mgr.stats["drains"] == 1
    # boundary at persist index 4 -> epoch 1 (quota 1): the next persist
    # advances the epoch and drains down to a single dirty entry
    mgr.persist("s5", 5, np.full(8, 5))
    assert mgr._epoch == 1
    assert mgr.stats["drains"] == 4
    dirty = [k for k, st in mgr._states.items()
             if st == ShardState.DIRTY]
    assert dirty == [("s5", 5)]
    # the drainer still lands everything durably after the step
    mgr.drain_all(wait=True)
    for v in range(1, 6):
        rec = mgr.store.read(f"s{v}")
        assert rec is not None and rec[0] == v
    mgr.close()


def test_scheduled_crash_zero_acks_nothing(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    mgr.schedule_crash(0)
    mgr.persist("w", 1, np.ones(4))
    assert mgr.stats["acks"] == 0
    mgr.recover()
    assert mgr.store.read("w") is None
    mgr.close()


def test_replica_failure_falls_back_to_store(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    mgr.persist("w", 1, np.ones(4))
    mgr.drain_all(wait=True)
    # now kill every replica of the buffered copy
    for (s, v) in mgr.buffer.entries():
        for _ in range(mgr.buffer.replicas):
            mgr.buffer.fail_replica(s, v)
    got = mgr.restore("w")
    assert got is not None and got[0] == 1
    assert mgr.stats["restore_from_store"] >= 1
    mgr.close()


def test_capacity_stall_then_drain(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, cap_mb=1, sync=False)
    big = np.zeros(200_000, dtype=np.float32)   # 0.8 MB each
    mgr.persist("a", 1, big)
    mgr.persist("b", 1, big)                    # must evict a first
    assert mgr.stats["stalls"] >= 1
    assert mgr.restore("b")[0] == 1
    mgr.close()


@pytest.mark.slow
def test_one_drainer_per_queue_after_slow_crash_recover(tmp_path):
    """Drainer lifecycle (ROADMAP / PR 3 review): a slow DurableStore
    write outlives crash()'s 1 s join, so the old drain loop is still
    alive when recover() restarts the drainer.  The old thread must exit
    on its own (private stop event) without ever consuming from the new
    queue, and _start_drainer must refuse to double-spawn while the
    active drainer lives."""
    mgr = mk(tmp_path, PersistScheme.PB, sync=False, delay=1.5)
    mgr.persist("a", 1, np.ones(8))
    time.sleep(0.3)                 # drainer is now inside the slow write
    old = mgr._drainer
    mgr.crash()                     # join(1.0) times out; old still alive
    assert old.is_alive(), "precondition: the slow write must outlive crash"
    mgr.recover()
    new = mgr._drainer
    assert new is not old and new.is_alive()
    # double-start refuses while the active drainer lives
    mgr._start_drainer()
    assert mgr._drainer is new, "_start_drainer must not double-spawn"
    # the stale thread exits once its in-flight write returns — it never
    # loops on the successor's queue (its queue binding is the abandoned
    # pre-crash queue, its stop event stays set)
    old.join(timeout=8.0)
    assert not old.is_alive(), "stopped drainer must exit, not keep looping"
    assert mgr._drainer is new and new.is_alive()
    # and the manager still works end to end
    mgr.persist("b", 2, np.zeros(4))
    mgr.drain_all(wait=True)
    assert mgr.store.read("b")[0] == 2
    assert mgr.store.read("a") is not None, "survivor lost in recovery"
    mgr.close()


def test_concurrent_persists(tmp_path):
    mgr = mk(tmp_path, PersistScheme.PB_RF, sync=False)
    errs = []

    def worker(i):
        try:
            for v in range(1, 6):
                mgr.persist(f"w{i}", v, np.full(16, v))
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs
    mgr.drain_all()
    for i in range(4):
        assert mgr.store.read(f"w{i}")[0] == 5
    mgr.close()
