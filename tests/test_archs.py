"""Per-assigned-architecture smoke tests: a REDUCED same-family config
runs one forward + one train step on CPU, asserting shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.steps import make_train_step
from repro.models.transformer import forward, init_params
from repro.optim import AdamWConfig, adamw_init

# per-arch jit of a full train step dominates suite wall time
pytestmark = pytest.mark.slow

B, S = 2, 24


def _batch(cfg, rng):
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    labels = np.roll(toks, -1, 1).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, S // 4, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)

    logits, _ = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    opt_cfg = AdamWConfig(lr=1e-3, total_steps=10)
    opt_state = adamw_init(opt_cfg, params)
    step = make_train_step(cfg, opt_cfg)
    p2, o2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts(arch):
    """Full configs instantiate abstractly and match published sizes."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "seamless-m4t-large-v2": (0.8e9, 1.4e9),
        "gemma2-2b": (2.0e9, 3.2e9),
        "deepseek-67b": (60e9, 70e9),
        "smollm-135m": (0.12e9, 0.15e9),
        "gemma3-12b": (10e9, 13e9),
        "jamba-1.5-large-398b": (380e9, 410e9),
        "phi3.5-moe-42b": (39e9, 44e9),
        "mixtral-8x7b": (45e9, 48e9),
        "mamba2-1.3b": (1.2e9, 1.5e9),
        "paligemma-3b": (2.2e9, 3.2e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, n)


def test_moe_active_param_counts():
    assert 6.0e9 < get_config("phi3.5-moe-42b").active_param_count() < 7.0e9
    assert 12e9 < get_config("mixtral-8x7b").active_param_count() < 14e9
    assert 90e9 < get_config("jamba-1.5-large-398b").active_param_count() < 96e9
