"""Multi-tenant shared-switch scale-out: partition, accounting, sweeps.

Acceptance (ISSUE 3):
  * a {workload x scheme x tenant-count} sweep lowers to ONE XLA program
    (the tenant count is a traced config scalar; only the per-tenant
    stats row count is a static shape);
  * per-tenant stats sum to the global ``SimResult`` bit-exactly for
    single-tenant configs — widening the stats matrix changes nothing;
  * barriers are tenant-local: independent hosts never synchronize.
"""
import numpy as np
import pytest

from conftest import TINY_BUCKET
from repro.core import (Op, PCSConfig, Scheme, Trace, compose_tenants,
                        make_tenant_trace, make_trace, tenant_ids)
from repro.core.engine import compile_count, simulate, simulate_grid
from repro.core.engine.state import (N_STATS, S_PERSIST_CNT, S_READ_CNT,
                                     result_from_stats)

FIELDS = ("runtime_ns", "persist_lat_ns", "read_lat_ns", "persists",
          "pm_reads", "read_hits", "coalesces", "pm_writes", "stall_ns",
          "pi_detours", "victim_drains", "acked_persists",
          "durable_persists")

TENANT_BUDGET = 60


@pytest.fixture(scope="module")
def two_tenant_trace():
    return make_tenant_trace("radiosity", 2, 2,
                             persist_budget=TENANT_BUDGET)


def _exact_equal(a, b, label):
    for f in FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        assert va == vb or va == pytest.approx(vb, rel=1e-15), (
            label, f, va, vb)


# ---------------------------------------------------------------------------
# T=1 bit-exactness: the widened per-tenant stats layout is invisible
# ---------------------------------------------------------------------------

def test_t1_config_bit_exact_inside_multi_tenant_grid(two_tenant_trace):
    """A T=1 config inside a grid whose static stats shape is (2, N)
    must reproduce the standalone (1, N)-shaped run bit-exactly: the
    padding row provably stays zero and summation adds exact zeros."""
    tr = two_tenant_trace
    cfgs = [PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=1),
            PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2)]
    cells = simulate_grid([tr], cfgs, bucket=TINY_BUCKET)[0]
    solo = simulate(tr, cfgs[0], bucket=TINY_BUCKET)
    _exact_equal(cells[0], solo, "T1-in-T2-grid")
    assert cells[0].tenant_stats is None
    # and tenancy never changes WHAT happens on a barrier-consistent
    # trace — only the accounting: global counters match across T
    for f in ("persists", "pm_reads", "read_hits", "coalesces",
              "pm_writes", "victim_drains"):
        assert getattr(cells[1], f) == getattr(cells[0], f), f


def test_per_tenant_rows_sum_to_global(two_tenant_trace):
    r = simulate(two_tenant_trace,
                 PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2),
                 bucket=TINY_BUCKET)
    assert r.n_tenants == 2 and r.tenant_stats is not None
    assert r.tenant_stats.shape == (2, N_STATS)
    rows = r.tenant_results()
    assert sum(t.persists for t in rows) == r.persists
    assert sum(t.pm_reads for t in rows) == r.pm_reads
    assert sum(t.read_hits for t in rows) == r.read_hits
    assert sum(t.stall_ns for t in rows) == pytest.approx(r.stall_ns)
    # every tenant issued exactly its own trace's persist ops
    tids = tenant_ids(two_tenant_trace.lengths, 2)
    for t in range(2):
        want = int(sum((two_tenant_trace.ops[c, :l] == int(Op.PERSIST)).sum()
                       for c, l in enumerate(two_tenant_trace.lengths)
                       if tids[c] == t))
        assert rows[t].persists == want, t


def test_tenant_sweep_single_compile():
    """{workload x scheme x tenant-count} in ONE XLA program."""
    traces = [make_tenant_trace("radiosity", t, 2,
                                persist_budget=TENANT_BUDGET)
              for t in (1, 2, 4)]
    configs = [PCSConfig(scheme=s, n_tenants=t, n_cores=2 * t)
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)
               for t in (1, 2, 4)]
    c0 = compile_count()
    cells = simulate_grid(traces, configs, bucket=TINY_BUCKET)
    assert compile_count() - c0 == 1, (
        "tenant-count sweep must share one XLA program")
    for i, row in enumerate(cells):
        for j, r in enumerate(row):
            if configs[j].n_tenants == (1, 2, 4)[i]:
                assert r.persists > 0, (i, j)


# ---------------------------------------------------------------------------
# Tenant-local barriers
# ---------------------------------------------------------------------------

def _barriered(n_barriers, n_persists, base_addr):
    ops, addrs = [], []
    for i in range(n_persists):
        ops.append(int(Op.PERSIST))
        addrs.append(base_addr + i)
        if i < n_barriers:
            ops.append(int(Op.BARRIER))
            addrs.append(0)
    return ops, addrs


def test_barriers_are_tenant_local():
    """Two hosts with *different* barrier structures run to completion
    side by side: under the old global barrier the mismatch deadlocks
    (blocked cores never release), per-tenant barriers never cross."""
    o0, a0 = _barriered(3, 4, 0)
    o1, a1 = _barriered(0, 4, 100)
    L = max(len(o0), len(o1))

    def pad(x):
        return x + [0] * (L - len(x))

    ops = np.array([pad(o0), pad(o0), pad(o1), pad(o1)], np.int32)
    addrs = np.array([pad(a0), pad(a0), pad(a1), pad(a1)], np.int32)
    gaps = np.full((4, L), 3000.0, np.float32)
    lengths = np.array([len(o0), len(o0), len(o1), len(o1)], np.int32)
    tr = Trace(ops=ops, addrs=addrs, gaps=gaps, lengths=lengths, name="bar")

    r2 = simulate(tr, PCSConfig(scheme=Scheme.PB, n_cores=4, n_tenants=2),
                  bucket=64)
    assert r2.persists == 16          # all four cores finished
    r1 = simulate(tr, PCSConfig(scheme=Scheme.PB, n_cores=4, n_tenants=1),
                  bucket=64)
    assert r1.persists < 16           # global barrier: tenant-0 deadlocks


# ---------------------------------------------------------------------------
# Composer
# ---------------------------------------------------------------------------

def test_compose_tenants_disjoint_address_spaces():
    parts = [make_trace("raytrace", n_cores=2, seed=s, persist_budget=40)
             for s in (0, 1, 2)]
    tr = compose_tenants(parts)
    assert tr.n_cores == 6
    tids = tenant_ids(tr.lengths, 3)
    pm = lambda t, rows: {                                    # noqa: E731
        int(a) for c in rows for a, o in zip(
            tr.addrs[c, :tr.lengths[c]], tr.ops[c, :tr.lengths[c]])
        if o in (int(Op.PM_READ), int(Op.PERSIST)) and a < (1 << 24)}
    spaces = [pm(t, np.nonzero(tids == t)[0]) for t in range(3)]
    for i in range(3):
        for j in range(i + 1, 3):
            assert not (spaces[i] & spaces[j]), (i, j)


def test_compose_tenants_shared_hot_set():
    parts = [make_trace("radiosity", n_cores=2, seed=s, persist_budget=40)
             for s in (0, 1)]
    hot = 18                                    # radiosity's hot set
    tr = compose_tenants(parts, shared_lines=hot)
    tids = tenant_ids(tr.lengths, 2)
    per_tenant = []
    for t in range(2):
        lines = set()
        for c in np.nonzero(tids == t)[0]:
            for a, o in zip(tr.addrs[c, :tr.lengths[c]],
                            tr.ops[c, :tr.lengths[c]]):
                if o == int(Op.PERSIST) and a < hot:
                    lines.add(int(a))
        per_tenant.append(lines)
    # the hot window is genuinely shared across tenants
    assert per_tenant[0] & per_tenant[1]


def test_compose_tenants_rejects_uneven_cores():
    a = make_trace("raytrace", n_cores=2, persist_budget=20)
    b = make_trace("raytrace", n_cores=3, persist_budget=20)
    with pytest.raises(ValueError, match="equal core counts"):
        compose_tenants([a, b])


def test_compose_tenants_rejects_overlapping_stride():
    """An explicit addr_stride narrower than the PM footprint would
    silently alias different tenants' 'private' windows."""
    parts = [make_trace("raytrace", n_cores=2, seed=s, persist_budget=20)
             for s in (0, 1)]
    with pytest.raises(ValueError, match="overlap"):
        compose_tenants(parts, addr_stride=4)


def test_result_from_stats_padding_rows_exact():
    """Summation over provably-zero padding rows is bit-exact."""
    rng = np.random.default_rng(0)
    row = rng.uniform(0.0, 1e9, (N_STATS,))
    row[S_PERSIST_CNT] = 7.0
    row[S_READ_CNT] = 3.0
    padded = np.zeros((4, N_STATS))
    padded[0] = row
    a = result_from_stats(1.0, row)
    b = result_from_stats(1.0, padded)
    for f in ("persist_lat_ns", "read_lat_ns", "stall_ns", "persists",
              "pm_reads"):
        assert getattr(a, f) == getattr(b, f), f
