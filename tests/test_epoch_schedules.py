"""Epoched config schedules: construction, lowering and crash semantics.

Three contracts around ``params.Schedule`` (piecewise-constant knob
schedules lowered to ``(E,)``/``(E, T)`` operand rows plus one shared
epoch-boundary vector):

  * **validation** — malformed schedules and knob sets that disagree on
    the shared boundary vector are rejected at construction, never
    silently mis-lowered;
  * **single-epoch identity** — a ``Schedule`` with no boundaries is the
    *same config* as the bare scalar: the lowered operand dict is
    byte-equal (no ``epoch_bounds`` key, identical dtypes/values), and a
    shared grid returns bit-identical SimResults for both columns;
  * **issue-time semantics** — entries keep the epoch of their *issue*
    instant: a placement flip migrates nothing, so a crash after the
    boundary attributes epoch-0 entries to their issue-time leaf
    (oracle + engine agree; the differential matrix in
    tests/test_crash_differential.py pins the full cross product).
"""
import numpy as np
import pytest

from _crash_driver import assert_cell_matches, oracle_replay
from repro.core import (AllocPolicy, DrainPolicy, FabricTopology, PBPolicy,
                        PCSConfig, Schedule, Scheme, fuzz_crash_ns,
                        fuzz_trace, leaf_placement, tenant_ids)
from repro.core.engine import compile_count, simulate_grid
from repro.core.engine.state import EPOCH_KEYS, scalars_from_config
from repro.core.params import (epoch_index, epoch_value, n_epochs_of,
                               resolve_epoch, shared_boundaries)
from repro.core.semantics import PersistentBuffer
from test_crash_differential import _assert_simresults_identical

N_ADDRS = 6
N_SLOTS = 50
BUCKET = 128


# ------------------------------------------------------------ validation
def test_schedule_validation_rejects_malformed():
    with pytest.raises(ValueError, match="values"):
        Schedule((1.0e6,), (0.5,))          # need boundaries + 1 values
    with pytest.raises(ValueError, match="increasing"):
        Schedule((2.0e6, 1.0e6), (0.5, 0.5, 0.5))
    with pytest.raises(ValueError, match="positive"):
        Schedule((-1.0,), (0.5, 0.5))
    with pytest.raises(ValueError, match="positive"):
        Schedule((float("inf"),), (0.5, 0.5))
    # per-epoch policy validation: every epoch must satisfy the same
    # invariants a static config would
    with pytest.raises(ValueError, match="preset"):
        DrainPolicy(threshold=Schedule((1.0e6,), (0.75, 0.25)),
                    preset=0.5)             # epoch 1: preset > threshold
    with pytest.raises(ValueError, match="quota"):
        PCSConfig(scheme=Scheme.PB_RF, n_pbe=4, n_tenants=2,
                  policy=PBPolicy(alloc=AllocPolicy(
                      tenant_quota=Schedule((1.0e6,),
                                            ((2, 2), (4, 4))))))
    # every scheduled knob of one config must share ONE boundary vector
    # (the engine lowers a single shared epoch axis)
    with pytest.raises(ValueError, match="share one boundary vector"):
        PCSConfig(scheme=Scheme.PB_RF, n_pbe=8, policy=PBPolicy(
            drain=DrainPolicy(
                threshold=Schedule((2.0e6,), (0.75, 0.5)),
                preset=Schedule((1.0e6,), (0.25, 0.25)))))
    # scheduled placement: every epoch's tuple is validated
    with pytest.raises(ValueError, match="placement"):
        FabricTopology(2, (4, 4), 4,
                       Schedule((1.0e6,), ((0, 1), (0, 2))))


def test_epoch_helpers_boundary_belongs_to_new_epoch():
    sch = Schedule((1.0e6, 2.0e6), (10, 20, 30))
    assert sch.n_epochs == 3
    # the boundary instant belongs to the NEW epoch (crash-gate twin)
    assert [epoch_index(sch.boundaries_ns, t)
            for t in (0.0, 0.5e6, 1.0e6, 1.5e6, 2.0e6, 9e9)] \
        == [0, 0, 1, 1, 2, 2]
    assert sch.value_at(1.0e6) == 20
    # epochs past the last value clamp to it (short schedules in a
    # wider grid keep their final value)
    assert epoch_value(sch, 7) == 30
    assert epoch_value(0.75, 3) == 0.75     # scalars pass through
    assert n_epochs_of(0.5, sch, None) == 3
    assert shared_boundaries(0.5, None) == ()
    # resolve_epoch reconstructs a plain (schedule-free) policy
    pol = PBPolicy(drain=DrainPolicy(
        threshold=Schedule((1.0e6,), (0.75, 0.5)), preset=0.25))
    assert resolve_epoch(pol, 0).drain.threshold == 0.75
    assert resolve_epoch(pol, 1).drain.threshold == 0.5


def test_grid_rejects_undersized_epoch_bound():
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_pbe=8, policy=PBPolicy(
        drain=DrainPolicy(threshold=Schedule((1.0e6,), (0.75, 0.5)),
                          preset=0.25)))
    assert cfg.n_epochs == 2
    with pytest.raises(ValueError, match="epoch bound"):
        scalars_from_config(cfg, n_tenants_max=1, n_epochs_max=1)


# --------------------------------------------- single-epoch == scalar pin
def test_single_epoch_schedule_lowers_byte_identical():
    """A boundary-free Schedule on every schedulable knob must lower to
    the exact dict a scalar config lowers to — same keys (no
    ``epoch_bounds``), same dtypes, same bytes — so single-epoch grids
    provably share the schedule-free XLA program."""
    n_tenants = 2
    fab_s = FabricTopology(2, (4, 4), 4,
                           leaf_placement(n_tenants, 2, "packed"))
    fab_e = FabricTopology(2, (4, 4), 4, Schedule(
        (), (leaf_placement(n_tenants, 2, "packed"),)))
    scalar = PCSConfig(
        scheme=Scheme.PB_RF, n_cores=4, n_tenants=n_tenants, fabric=fab_s,
        policy=PBPolicy(drain=DrainPolicy(threshold=0.75, preset=0.25,
                                          latency_target_ns=5e3),
                        alloc=AllocPolicy(tenant_quota=(3, 3))))
    sched = PCSConfig(
        scheme=Scheme.PB_RF, n_cores=4, n_tenants=n_tenants, fabric=fab_e,
        policy=PBPolicy(drain=DrainPolicy(
            threshold=Schedule((), (0.75,)),
            preset=Schedule((), (0.25,)),
            latency_target_ns=Schedule((), (5e3,))),
            alloc=AllocPolicy(tenant_quota=Schedule((), ((3, 3),)))))
    assert scalar.n_epochs == 1 and sched.n_epochs == 1
    a = scalars_from_config(scalar, n_tenants, 1, 2)
    b = scalars_from_config(sched, n_tenants, 1, 2)
    assert "epoch_bounds" not in a and "epoch_bounds" not in b
    assert set(a) == set(b)
    for k in a:
        xa, xb = np.asarray(a[k]), np.asarray(b[k])
        assert xa.dtype == xb.dtype and xa.shape == xb.shape, k
        assert xa.tobytes() == xb.tobytes(), k


def test_single_epoch_schedule_simresults_bit_identical():
    """Both spellings in ONE shared grid: every SimResult field of the
    scalar column equals the single-epoch-Schedule column bitwise, at a
    mid-run crash point and uncrashed."""
    n_tenants, n_cores = 2, 4
    traces = [fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS,
                         n_addrs=N_ADDRS, n_tenants=n_tenants,
                         p_persist=0.7)[0] for s in range(2)]
    def mk(threshold, quota):
        return PBPolicy(drain=DrainPolicy(threshold=threshold,
                                          preset=0.25),
                        alloc=AllocPolicy(tenant_quota=quota))
    pairs = []
    for k in (23, N_SLOTS):
        pairs.append((mk(0.75, (3, 3)),
                      mk(Schedule((), (0.75,)),
                         Schedule((), ((3, 3),)))))
    configs = []
    for k, (pol_s, pol_e) in zip((23, N_SLOTS), pairs):
        for pol in (pol_s, pol_e):
            configs.append(PCSConfig(
                scheme=Scheme.PB_RF, n_pbe=8, n_cores=n_cores,
                n_tenants=n_tenants,
                policy=pol).with_crash(fuzz_crash_ns(k)))
    c0 = compile_count()
    cells = simulate_grid(traces, configs, max_pbe=8, bucket=BUCKET,
                          track_addrs=N_ADDRS)
    assert compile_count() - c0 <= 1
    for i in range(len(traces)):
        for j in range(0, len(configs), 2):
            _assert_simresults_identical(
                cells[i][j], cells[i][j + 1],
                ("single-epoch==scalar", i, j))


# ----------------------------------------------- issue-time epoch crashes
def test_mid_epoch_crash_recovers_issue_time_leaf():
    """Placement-at-issue: a tenant's entries persisted under epoch 0's
    placement stay on that leaf after the epoch-1 flip — recovery (and
    the per-leaf crash attribution) finds them on the *issue-time*
    leaf, in the oracle and in the engine."""
    # oracle-level: persist under epoch 0, flip, crash — no migration
    place0, place1 = (0, 0, 1, 1), (1, 1, 0, 0)
    fab = FabricTopology(2, (4, 4), 4,
                         Schedule((1.0e6,), (place0, place1)))
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=4,
                    fabric=fab)
    pb = PersistentBuffer(cfg)
    assert pb._placement == place0
    for a in range(3):                       # tenant 0 -> leaf 0
        pb.persist(a, ("e0", a), tenant=0)
    pb.set_epoch(pb.epoch_at(2.0e6))         # past the boundary
    assert pb.epoch == 1 and pb._placement == place1
    pb.persist(3, ("e1", 3), tenant=0)       # now lands on leaf 1
    before = pb.snapshot_durable()
    leaves = pb.leaf_surviving()
    assert leaves[0] == 3 and leaves[1] == 1, leaves
    pb.crash()
    pb.recover()
    # every issued version survives recovery regardless of which
    # epoch's leaf held it
    assert {a: rec[0] for a, rec in pb.pm.store.items()} \
        == {a: rec[0] for a, rec in before.items()}

    # engine-level: crash in epoch 1, exact per-leaf agreement with the
    # epoch-aware oracle at the issue-time attribution
    n_tenants, n_cores = 4, 4
    trace, sched = fuzz_trace(7, n_cores=n_cores, n_slots=N_SLOTS,
                              n_addrs=N_ADDRS, n_tenants=n_tenants,
                              p_persist=0.8)
    bound = fuzz_crash_ns(25)
    fab2 = FabricTopology(2, (4, 4), 4,
                          Schedule((bound,), (place0, place1)))
    crash_slot = 36                          # mid-epoch-1 crash
    cfg2 = PCSConfig(scheme=Scheme.PB_RF, n_cores=n_cores,
                     n_tenants=n_tenants,
                     fabric=fab2).with_crash(fuzz_crash_ns(crash_slot))
    res = simulate_grid([trace], [cfg2], max_pbe=8, bucket=BUCKET,
                        track_addrs=N_ADDRS)[0][0]
    oracle = oracle_replay(sched, crash_slot, Scheme.PB_RF, 8,
                           core_tenant=tenant_ids(trace.lengths,
                                                  n_tenants),
                           n_tenants=n_tenants, fabric=fab2)
    assert_cell_matches(res, oracle, N_ADDRS, label=("mid-epoch-crash",))


def test_abort_reason_registry_matches_engine():
    """benchmarks._sweeps duplicates the abort-reason names so it stays a
    leaf module; this pins the copy to the engine's one-hot row order —
    a new abort reason can't ship without its bench telemetry key."""
    from benchmarks._sweeps import ABORT_REASONS
    from repro.core.engine.macro import MACRO_ABORT_REASONS
    assert ABORT_REASONS == MACRO_ABORT_REASONS
