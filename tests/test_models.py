"""Model-family behaviour: forward/loss sanity and the decode-vs-forward
teacher-forcing consistency contract for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.models.transformer import (LayerSpec, ModelConfig, decode_step,
                                      forward, init_params, loss_fn, prefill)

KEY = jax.random.key(0)
B, S, V = 2, 32, 128


def _check(cfg, batch_extra=None, serve=True):
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if batch_extra:
        batch.update(batch_extra)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    if serve:
        pre = dict(batch)
        pre["tokens"] = toks[:, :S - 1]
        _, caches = prefill(cfg, params, pre, max_len=S + 4)
        dec, _ = decode_step(cfg, params, toks[:, S - 1:S], caches,
                             pos0=jnp.asarray(S - 1, jnp.int32))
        ref = logits[:, S - 1]
        rel = (float(jnp.max(jnp.abs(dec - ref)))
               / (float(jnp.max(jnp.abs(ref))) + 1e-6))
        assert rel < 2e-2, f"{cfg.name}: decode/forward rel err {rel}"
    return float(loss)


def test_dense():
    _check(ModelConfig("dense", n_layers=4, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab=V, remat=False,
                       dtype=jnp.float32))


def test_local_global_softcap():
    _check(ModelConfig("g2", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=V, window=8, attn_softcap=50.0,
                       final_softcap=30.0,
                       block_pattern=(LayerSpec("swa"), LayerSpec("attn")),
                       remat=False, dtype=jnp.float32))


def test_five_to_one_qknorm():
    _check(ModelConfig("g3", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=V, window=8, qk_norm=True,
                       block_pattern=tuple([LayerSpec("swa")] * 5
                                           + [LayerSpec("attn")]),
                       remat=False, dtype=jnp.float32))


def test_moe():
    _check(ModelConfig("moe", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=V, window=8, n_experts=4,
                       capacity_factor=8.0,
                       block_pattern=(LayerSpec("swa", moe=True),),
                       remat=False, dtype=jnp.float32))


def test_pure_ssm():
    _check(ModelConfig("ssm", n_layers=4, d_model=64, n_heads=1, n_kv_heads=1,
                       d_ff=0, vocab=V, ssm_state=16, ssm_head_dim=16,
                       block_pattern=(LayerSpec("ssm"),),
                       remat=False, dtype=jnp.float32))


def test_hybrid():
    _check(ModelConfig("hyb", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=V, n_experts=4, capacity_factor=8.0,
                       ssm_state=16, ssm_head_dim=16,
                       block_pattern=(LayerSpec("ssm"),
                                      LayerSpec("ssm", moe=True),
                                      LayerSpec("attn"),
                                      LayerSpec("ssm", moe=True)),
                       remat=False, dtype=jnp.float32))


def test_enc_dec():
    d = 64
    _check(ModelConfig("ed", n_layers=2, d_model=d, n_heads=4, n_kv_heads=4,
                       d_ff=128, vocab=V, n_enc_layers=2, frontend="audio",
                       remat=False, dtype=jnp.float32),
           batch_extra={"enc_embeds": np.random.default_rng(0)
                        .standard_normal((B, 16, d)).astype(np.float32)},
           serve=False)


def test_vision_prefix():
    d = 64
    _check(ModelConfig("vlm", n_layers=2, d_model=d, n_heads=4, n_kv_heads=1,
                       d_ff=128, vocab=V, frontend="vision", frontend_seq=8,
                       remat=False, dtype=jnp.float32),
           batch_extra={"prefix_embeds": np.random.default_rng(0)
                        .standard_normal((B, 8, d)).astype(np.float32)},
           serve=False)


def test_remat_matches_no_remat():
    kw = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
              vocab=V, dtype=jnp.float32)
    c1 = ModelConfig("r0", remat=False, **kw)
    c2 = ModelConfig("r1", remat=True, **kw)
    p = init_params(c1, KEY)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    batch = {"tokens": toks, "labels": toks}
    l1 = loss_fn(c1, p, batch)
    l2 = loss_fn(c2, p, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda q: loss_fn(c1, q, batch))(p)
    g2 = jax.grad(lambda q: loss_fn(c2, q, batch))(p)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-5, err


def test_multi_step_decode_matches_forward():
    """Greedy decode K steps == teacher forcing on the argmax stream."""
    cfg = ModelConfig("dec", n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
                      d_ff=96, vocab=V, remat=False, dtype=jnp.float32)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, V)
    last_logits, caches = prefill(cfg, params, {"tokens": toks}, max_len=16)
    seq = [toks]
    logit_steps = []
    for i in range(4):
        cur = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
        seq.append(cur)
        last_logits, caches = decode_step(cfg, params, cur, caches)
        logit_steps.append(last_logits)
    full = jnp.concatenate(seq, axis=1)          # (1, 12)
    logits, _ = forward(cfg, params, {"tokens": full, "labels": full})
    for i in range(4):
        ref = logits[:, 8 + i]                   # logits after token 8+i
        got = logit_steps[i]
        rel = (float(jnp.max(jnp.abs(got - ref)))
               / (float(jnp.max(jnp.abs(ref))) + 1e-6))
        assert rel < 1e-3, (i, rel)


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation over microbatches == one full-batch step."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    cfg = ModelConfig("mb", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab=64, remat=False, dtype=jnp.float32)
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    params = init_params(cfg, KEY)
    opt = adamw_init(opt_cfg, params)
    toks = jax.random.randint(jax.random.key(5), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    p1, _, m1 = make_train_step(cfg, opt_cfg)(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, opt_cfg, microbatches=2)(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert err < 1e-5, err
