"""The batched engine front-end: one program for the whole paper grid.

Acceptance for the core.engine refactor: ``simulate_grid`` runs a
mixed-scheme {7 workloads x NoPB/PB/PB_RF} grid with exactly ONE XLA
compilation (the scheme is traced, not static), and every per-cell
``SimResult`` matches what ``simulate()`` returns for that cell.  The
grid itself comes from the session-scoped ``paper_grid`` fixture
(conftest.py) so its single compilation is shared across the suite.

The padding-invariant tests assert directly on the final
:class:`MachineState` (``scan_cell(..., return_state=True)``): padded
cores issue no ops and padded steps change no stats.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from conftest import TINY_BUCKET
from repro.core import Op, PCSConfig, Scheme, Trace, make_trace
from repro.core.engine import simulate, simulate_grid, simulate_sweep
from repro.core.engine.state import scalars_from_config
from repro.core.engine.step import scan_cell

FIELDS = ("runtime_ns", "persist_lat_ns", "read_lat_ns", "persists",
          "pm_reads", "read_hits", "coalesces", "pm_writes", "stall_ns",
          "pi_detours", "victim_drains")


def _assert_cells_equal(a, b, label):
    for f in FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if isinstance(va, int):
            assert va == vb, (label, f, va, vb)
        else:
            assert va == pytest.approx(vb, rel=1e-12), (label, f, va, vb)


def test_mixed_scheme_grid_single_compile(paper_grid):
    names, configs, cells, compiles = paper_grid
    assert compiles == 1, (
        "mixed-scheme grid must lower to exactly one XLA program")
    assert len(cells) == len(names) and all(
        len(row) == len(configs) for row in cells)
    for row in cells:
        for cell in row:
            assert cell.persists > 0 and cell.runtime_ns > 0


def test_grid_cells_match_simulate_spotcheck(paper_grid, tiny_traces):
    """Three representative cells re-run standalone; the exhaustive
    21-cell sweep is the slow variant below."""
    names, configs, cells, _ = paper_grid
    picks = [("radiosity", 2), ("cholesky", 0), ("fft", 1)]
    for name, j in picks:
        i = names.index(name)
        ref = simulate(tiny_traces[name], configs[j], bucket=TINY_BUCKET)
        _assert_cells_equal(cells[i][j], ref, (name, j))


@pytest.mark.slow
def test_grid_cells_match_simulate_exhaustive(paper_grid, tiny_traces):
    names, configs, cells, _ = paper_grid
    for i, name in enumerate(names):
        for j, cfg in enumerate(configs):
            ref = simulate(tiny_traces[name], cfg, bucket=TINY_BUCKET)
            _assert_cells_equal(cells[i][j], ref, (name, cfg.scheme.name))


def test_grid_results_invariant_to_bucket(paper_grid, tiny_traces):
    """Padding steps are no-ops: shape-bucket choice changes nothing."""
    names, configs, cells, _ = paper_grid
    i = names.index("radiosity")
    b = simulate(tiny_traces["radiosity"], configs[2],
                 bucket=2 * TINY_BUCKET)
    _assert_cells_equal(cells[i][2], b, "bucket")


def test_sweep_allows_mixed_schemes(tiny_traces):
    """simulate_sweep no longer refuses mixed-scheme config lists."""
    tr = tiny_traces["raytrace"]
    cfgs = [PCSConfig(scheme=Scheme.NOPB),
            PCSConfig(scheme=Scheme.PB, n_pbe=8),
            PCSConfig(scheme=Scheme.PB_RF, n_pbe=32)]
    sweep = simulate_sweep(tr, cfgs, bucket=TINY_BUCKET)
    assert len(sweep) == 3
    for cfg, r in zip(cfgs, sweep):
        ref = simulate(tr, cfg, max_pbe=32, bucket=TINY_BUCKET)
        _assert_cells_equal(r, ref, cfg.scheme.name)


def _one_core_trace():
    ops = [int(Op.PERSIST), int(Op.PM_READ)] * 8
    addrs = list(range(16))
    return Trace(ops=np.array([ops], np.int32),
                 addrs=np.array([addrs], np.int32),
                 gaps=np.full((1, 16), 2000.0, np.float32),
                 lengths=np.array([16], np.int32), name="c1")


@pytest.mark.slow
def test_grid_pads_heterogeneous_core_counts(tiny_traces):
    """Traces with different core counts share one stacked program; the
    padded cores never issue ops and never count toward barriers."""
    tr1 = _one_core_trace()
    tr8 = tiny_traces["radiosity"]                      # 8 cores
    cfg = PCSConfig(scheme=Scheme.PB)
    cells = simulate_grid([tr1, tr8], [cfg], bucket=TINY_BUCKET)
    _assert_cells_equal(cells[0][0],
                        simulate(tr1, cfg, bucket=TINY_BUCKET), "c1")
    _assert_cells_equal(cells[1][0],
                        simulate(tr8, cfg, bucket=TINY_BUCKET), "c8")


def test_grid_rejects_mixed_pm_banks(tiny_traces):
    tr = tiny_traces["radiosity"]
    with pytest.raises(ValueError, match="pm_banks"):
        simulate_grid([tr], [PCSConfig(pm_banks=4), PCSConfig(pm_banks=8)],
                      bucket=TINY_BUCKET)


def test_barrier_workload_in_grid(paper_grid, tiny_traces):
    """A barrier-heavy trace (FFT) completes and matches its single-cell
    run inside a stacked grid (regression: barrier release threshold must
    count only live cores)."""
    names, configs, cells, _ = paper_grid
    i = names.index("fft")
    ref = simulate(tiny_traces["fft"], configs[2], bucket=TINY_BUCKET)
    _assert_cells_equal(cells[i][2], ref, "fft-in-grid")
    assert ref.runtime_ns > 0


# --------------------------------------------------------------------------
# Padding invariants, asserted on MachineState itself (not end-to-end)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_cell(max_pbe, n_steps, pm_banks):
    import jax
    return jax.jit(functools.partial(
        scan_cell, max_pbe=max_pbe, n_steps=n_steps, pm_banks=pm_banks,
        n_track=0, return_state=True))


def _scan_state(tr, cfg, n_steps, extra_cores=0):
    """Run scan_cell with optional padded cores; return the final state."""
    C, L = tr.ops.shape
    ops = np.zeros((C + extra_cores, L), np.int32)
    addrs = np.zeros((C + extra_cores, L), np.int32)
    gaps = np.zeros((C + extra_cores, L), np.float32)
    lengths = np.zeros((C + extra_cores,), np.int32)
    ops[:C], addrs[:C], gaps[:C], lengths[:C] = (tr.ops, tr.addrs, tr.gaps,
                                                 tr.lengths)
    with enable_x64():
        sc = {k: jnp.asarray(v, jnp.float64)
              for k, v in scalars_from_config(cfg).items()}
        out = _jitted_cell(cfg.n_pbe, n_steps, cfg.pm_banks)(
            jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(gaps),
            jnp.asarray(lengths), jnp.asarray(int(cfg.scheme), jnp.int32),
            sc)
        state = jax.tree_util.tree_map(np.asarray, out[-1])
    return state


@pytest.fixture(scope="module")
def _barrier_trace():
    ops = np.array([[int(Op.PERSIST), int(Op.BARRIER), int(Op.PM_READ),
                     int(Op.PERSIST)],
                    [int(Op.PERSIST), int(Op.BARRIER), int(Op.PERSIST),
                     int(Op.COMPUTE)]], np.int32)
    addrs = np.array([[1, 0, 1, 2], [3, 0, 4, 0]], np.int32)
    gaps = np.full((2, 4), 3000.0, np.float32)
    return Trace(ops=ops, addrs=addrs, gaps=gaps,
                 lengths=np.array([4, 4], np.int32), name="pad")


@pytest.mark.parametrize("scheme", [Scheme.NOPB, Scheme.PB, Scheme.PB_RF])
def test_padded_cores_issue_no_ops(_barrier_trace, scheme):
    """A zero-length core leaves no trace in MachineState: its clock and
    cursor stay zero, it never arrives at a barrier, and every machine
    array (PB tables, resources, stats) matches the unpadded run."""
    cfg = PCSConfig(scheme=scheme, n_pbe=4)
    n = int(_barrier_trace.lengths.sum())
    st_ref = _scan_state(_barrier_trace, cfg, n_steps=n)
    st_pad = _scan_state(_barrier_trace, cfg, n_steps=n, extra_cores=2)
    assert np.all(np.asarray(st_pad.clock[2:]) == 0.0)
    assert np.all(np.asarray(st_pad.ptr[2:]) == 0)
    assert not np.any(np.asarray(st_pad.blocked[2:]))
    np.testing.assert_array_equal(np.asarray(st_pad.clock[:2]),
                                  np.asarray(st_ref.clock))
    for field in ("tag", "state", "lru", "dd", "ver", "pm_busy", "pbc_busy",
                  "bcount", "stats"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_pad, field)),
            np.asarray(getattr(st_ref, field)), err_msg=field)


@pytest.mark.parametrize("scheme", [Scheme.PB, Scheme.PB_RF])
def test_padded_steps_change_no_state(_barrier_trace, scheme):
    """Steps past stream exhaustion are provable no-ops: running the scan
    longer changes no MachineState field at all."""
    cfg = PCSConfig(scheme=scheme, n_pbe=4)
    n = int(_barrier_trace.lengths.sum())
    st_exact = _scan_state(_barrier_trace, cfg, n_steps=n)
    st_longer = _scan_state(_barrier_trace, cfg, n_steps=n + 17)
    for field in st_exact._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_longer, field)),
            np.asarray(getattr(st_exact, field)), err_msg=field)
