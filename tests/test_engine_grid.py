"""The batched engine front-end: one program for the whole paper grid.

Acceptance for the core.engine refactor: ``simulate_grid`` runs a
mixed-scheme {7 workloads x NoPB/PB/PB_RF} grid with exactly ONE XLA
compilation (the scheme is traced, not static), and every per-cell
``SimResult`` matches what ``simulate()`` returns for that cell.
"""
import numpy as np
import pytest

from repro.core import Op, PCSConfig, Scheme, Trace, WORKLOADS, make_trace
from repro.core.engine import (compile_count, simulate, simulate_grid,
                               simulate_sweep)

BUDGET = 400
BUCKET = 1024
TRACE_KW = {"fft": {"m": 9}}   # shrink the FFT read volume for test time
FIELDS = ("runtime_ns", "persist_lat_ns", "read_lat_ns", "persists",
          "pm_reads", "read_hits", "coalesces", "pm_writes", "stall_ns",
          "pi_detours", "victim_drains")


@pytest.fixture(scope="module")
def tiny_traces():
    return {name: make_trace(name, persist_budget=BUDGET,
                             **TRACE_KW.get(name, {}))
            for name in WORKLOADS}


def _assert_cells_equal(a, b, label):
    for f in FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if isinstance(va, int):
            assert va == vb, (label, f, va, vb)
        else:
            assert va == pytest.approx(vb, rel=1e-12), (label, f, va, vb)


def test_mixed_scheme_grid_single_compile_matches_simulate(tiny_traces):
    names = list(tiny_traces)
    traces = [tiny_traces[n] for n in names]
    configs = [PCSConfig(scheme=s)
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)]
    c0 = compile_count()
    cells = simulate_grid(traces, configs, bucket=BUCKET)
    assert compile_count() - c0 == 1, (
        "mixed-scheme grid must lower to exactly one XLA program")
    assert len(cells) == len(names) and all(
        len(row) == len(configs) for row in cells)
    for name, tr, row in zip(names, traces, cells):
        for cfg, cell in zip(configs, row):
            ref = simulate(tr, cfg, bucket=BUCKET)
            _assert_cells_equal(cell, ref, (name, cfg.scheme.name))


def test_grid_results_invariant_to_bucket(tiny_traces):
    """Padding steps are no-ops: shape-bucket choice changes nothing."""
    tr = tiny_traces["radiosity"]
    cfg = PCSConfig(scheme=Scheme.PB_RF)
    a = simulate(tr, cfg, bucket=BUCKET)
    b = simulate(tr, cfg, bucket=2 * BUCKET)
    _assert_cells_equal(a, b, "bucket")


def test_sweep_allows_mixed_schemes(tiny_traces):
    """simulate_sweep no longer refuses mixed-scheme config lists."""
    tr = tiny_traces["raytrace"]
    cfgs = [PCSConfig(scheme=Scheme.NOPB),
            PCSConfig(scheme=Scheme.PB, n_pbe=8),
            PCSConfig(scheme=Scheme.PB_RF, n_pbe=32)]
    sweep = simulate_sweep(tr, cfgs, bucket=BUCKET)
    assert len(sweep) == 3
    for cfg, r in zip(cfgs, sweep):
        ref = simulate(tr, cfg, max_pbe=32, bucket=BUCKET)
        _assert_cells_equal(r, ref, cfg.scheme.name)


def test_grid_pads_heterogeneous_core_counts():
    """Traces with different core counts share one stacked program; the
    padded cores never issue ops and never count toward barriers."""
    def one_core_trace():
        ops = [int(Op.PERSIST), int(Op.PM_READ)] * 8
        addrs = list(range(16))
        return Trace(ops=np.array([ops], np.int32),
                     addrs=np.array([addrs], np.int32),
                     gaps=np.full((1, 16), 2000.0, np.float32),
                     lengths=np.array([16], np.int32), name="c1")

    tr1 = one_core_trace()
    tr8 = make_trace("radiosity", persist_budget=200)   # 8 cores, barriers=0
    cfg = PCSConfig(scheme=Scheme.PB)
    cells = simulate_grid([tr1, tr8], [cfg], bucket=BUCKET)
    _assert_cells_equal(cells[0][0], simulate(tr1, cfg, bucket=BUCKET), "c1")
    _assert_cells_equal(cells[1][0], simulate(tr8, cfg, bucket=BUCKET), "c8")


def test_grid_rejects_mixed_pm_banks(tiny_traces):
    tr = tiny_traces["radiosity"]
    with pytest.raises(ValueError, match="pm_banks"):
        simulate_grid([tr], [PCSConfig(pm_banks=4), PCSConfig(pm_banks=8)],
                      bucket=BUCKET)


def test_barrier_workload_in_grid(tiny_traces):
    """A barrier-heavy trace (FFT) completes and matches its single-cell
    run inside a stacked grid (regression: barrier release threshold must
    count only live cores)."""
    tr = tiny_traces["fft"]
    cfg = PCSConfig(scheme=Scheme.PB_RF)
    cells = simulate_grid([tr, tiny_traces["radiosity"]], [cfg],
                          bucket=BUCKET)
    ref = simulate(tr, cfg, bucket=BUCKET)
    _assert_cells_equal(cells[0][0], ref, "fft-in-grid")
    assert ref.runtime_ns > 0
