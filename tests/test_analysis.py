"""Static-analysis subsystem tests: every pass provably fires on its
seeded-violation fixture (exact rule id + location), and the real tree
passes clean.

The fixture corpus lives in ``tests/fixtures/analysis/`` — small files
with deliberate contract violations that the passes must pin down to
the line.  ``repro.analysis.twin`` deliberately skips any corpus path
containing an ``analysis`` component, so the fixtures never leak into
the real-tree checks.
"""
from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

import numpy as np

from repro.analysis import run_all
from repro.analysis import dtypes, mirror, retrace, sweeps, twin
from repro.analysis.common import (normalize_stmt, parse_exemptions,
                                   parse_markers, rel)

FIX = Path(__file__).resolve().parent / "fixtures" / "analysis"


def by_rule(findings, rule):
    return [f for f in findings if f.rule == rule]


# ------------------------------------------------------------- retrace
def test_retrace_fires_on_baked_operand():
    def fn(sc):
        return sc["used"] * 2.0

    findings = retrace.check_traced(fn=fn, args=({"used": 1.0,
                                                  "baked": 2.0},))
    assert [f.rule for f in findings] == ["retrace-baked-static"]
    assert "'baked'" in findings[0].message


def test_retrace_clean_when_all_operands_live():
    def fn(sc):
        return sc["a"] + sc["b"]

    assert retrace.check_traced(fn=fn, args=({"a": 1.0, "b": 2.0},)) == []


def test_retrace_fires_on_baked_epoch_operand():
    # the schedule variant of the baked-static slip: the step indexes a
    # per-epoch row with a *Python* constant and never reads the shared
    # epoch_bounds vector, so DCE must prove the boundary operand dead
    spec = importlib.util.spec_from_file_location(
        "epoch_baked", FIX / "epoch_baked.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    findings = retrace.check_traced(
        fn=mod.step,
        args=({"quota": np.arange(2.0), "crash_at": 1.0,
               "epoch_bounds": np.full((1,), 9.9e9)},))
    assert [f.rule for f in findings] == ["retrace-baked-static"]
    assert "'epoch_bounds'" in findings[0].message


def test_retrace_registered_fields_fixture():
    # declaration-side half: a params-like dataclass grew a schedule
    # knob without registering it sweepable-or-static
    spec = importlib.util.spec_from_file_location(
        "params_bad", FIX / "params_bad.py")
    mod = importlib.util.module_from_spec(spec)
    # inspect.getsourcelines (the field anchor) resolves the class's
    # file through sys.modules, so the fixture must be registered
    sys.modules["params_bad"] = mod
    spec.loader.exec_module(mod)
    findings = retrace.check_registered_fields(
        [mod.BadPolicy],
        sweepable={"BadPolicy.threshold": ("threshold_count",)},
        static={})
    assert [f.rule for f in findings] == ["retrace-unregistered-field"]
    assert "BadPolicy.quota_schedule" in findings[0].message
    assert findings[0].file.endswith("params_bad.py")
    assert findings[0].line == 12  # the quota_schedule field line


# -------------------------------------------------------------- mirror
def test_mirror_fixture_rules_and_locations():
    path = FIX / "mirror_bad.py"
    findings = mirror.check_mirrors(
        paths=[path], expected={"pair": 2, "same": 2, "ghost": 1})

    skew = by_rule(findings, "mirror-skew")
    assert [(f.file, f.line) for f in skew] == [(rel(path), 11)]
    assert "mirror_bad.py:6" in skew[0].message

    dangling = by_rule(findings, "mirror-dangling-marker")
    # a bare-line marker attaches to the following line (here: EOF+1)
    assert [(f.file, f.line) for f in dangling] == [(rel(path), 45)]

    unknown = by_rule(findings, "mirror-unknown-group")
    assert [(f.file, f.line) for f in unknown] == [(rel(path), 26)]
    assert "'mystery'" in unknown[0].message

    missing = by_rule(findings, "mirror-missing-site")
    assert len(missing) == 1 and "'ghost'" in missing[0].message

    assert len(findings) == 4  # the 'same' group normalizes equal


def test_mirror_alpha_renaming_matches_carry_style_rebinding():
    # site_c (fresh binding, st.acc root) and site_d (carry-style
    # rebinding, bare name root) must normalize identically — that is
    # exactly the handler-vs-macro shape the real groups rely on.
    findings = mirror.check_mirrors(paths=[FIX / "mirror_bad.py"],
                                    expected={"same": 2})
    assert by_rule(findings, "mirror-skew") == []


def test_mirror_column_coverage_fixture():
    findings = mirror.check_column_coverage(
        families={"a": [("mirror_bad.py", "fam_a")],
                  "b": [("mirror_bad.py", "fam_b")],
                  "c": [("mirror_bad.py", "fam_c")]},
        base=FIX)
    assert all(f.rule == "mirror-missing-column" for f in findings)
    # fam_b exempts S_TWO with a reason -> clean; fam_c's exemption has
    # no reason -> flagged, and S_TWO therefore still counts as missing
    no_reason = [f for f in findings if "without a reason" in f.message]
    assert [(f.file, f.line) for f in no_reason] \
        == [(rel(FIX / "mirror_bad.py"), 40)]
    missing = [f for f in findings if "S_TWO" in f.message]
    assert len(missing) == 1 and "'c'" in missing[0].message
    assert len(findings) == 2


# ---------------------------------------------------------------- twin
def test_twin_policy_fixture():
    findings = twin.check_policy_fields(
        engine_paths=[FIX / "engine_bad.py"],
        oracle_paths=[FIX / "oracle_bad.py"],
        fields={"Fake.alpha": ("fake.py", 10),
                "Fake.beta": ("fake.py", 20)})
    oracle_miss = by_rule(findings, "twin-policy-oracle")
    engine_miss = by_rule(findings, "twin-policy-engine")
    assert [(f.file, f.line) for f in oracle_miss] == [("fake.py", 10)]
    assert "Fake.alpha" in oracle_miss[0].message
    assert [(f.file, f.line) for f in engine_miss] == [("fake.py", 20)]
    assert "Fake.beta" in engine_miss[0].message
    assert len(findings) == 2


# -------------------------------------------------------------- dtypes
def test_dtype_packing_fixture():
    findings = dtypes.check_packing(
        shapes={"a": ("int32", (4,)), "c": ("int8", (2,))},
        expected={"a": "int8", "b": "float64"},
        anchor_file=FIX / "grid_bad.py")
    assert [f.rule for f in findings] == ["dtype-packing"] * 3
    msgs = " | ".join(f.message for f in findings)
    assert "'a' is int32" in msgs           # widened column
    assert "'b' is registered but absent" in msgs
    assert "'c' is not in the packing" in msgs


def test_dtype_f32_leak_fixture():
    spec = importlib.util.spec_from_file_location(
        "leak_fixture", FIX / "leak_fixture.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    findings = dtypes.check_f32_leaks(fn=mod.leak,
                                      args=(np.float64(1.0),))
    assert [f.rule for f in findings] == ["dtype-f32-leak"]
    assert findings[0].file.endswith("leak_fixture.py")
    assert findings[0].line == 8


def test_dtype_donation_fixture():
    findings = dtypes.check_donation(path=FIX / "grid_bad.py")
    assert [f.rule for f in findings] == ["dtype-undonated"] * 2
    assert findings[0].line == 6           # _DONATED misses gaps, mlen
    assert "gaps, mlen" in findings[0].message
    assert findings[1].line == 9           # jit partial, no donation
    assert "run" in findings[1].message


# -------------------------------------------------------------- sweeps
def test_sweeps_fixture():
    findings = sweeps.check(bench_dir=FIX / "bench_bad")
    unreg = by_rule(findings, "sweep-unregistered")
    assert [(f.file, f.line) for f in unreg] \
        == [(rel(FIX / "bench_bad" / "fig_x.py"), 7)]
    assert "'rogue_sweep'" in unreg[0].message
    partial = by_rule(findings, "sweep-missing-key")
    assert len(partial) == 1
    assert "partial_sweep_compiles" in partial[0].message
    stale = by_rule(findings, "sweep-stale")
    assert [(f.file, f.line) for f in stale] \
        == [(rel(FIX / "bench_bad" / "_sweeps.py"), 5)]
    assert "'ghost_sweep'" in stale[0].message
    assert len(findings) == 3


def test_sweeps_fixture_unregistered_fabric():
    """A figure script emitting the full fabric_sweep_* telemetry
    without registering the sweep must produce exactly one
    sweep-unregistered finding — the guard that keeps fabric_sweep
    under check_compiles' one-XLA-program watch."""
    findings = sweeps.check(bench_dir=FIX / "bench_bad_fabric")
    assert [f.rule for f in findings] == ["sweep-unregistered"]
    assert "'fabric_sweep'" in findings[0].message
    assert findings[0].file == rel(
        FIX / "bench_bad_fabric" / "fig_fabric.py")


# ----------------------------------------------------- comment grammar
def test_marker_and_exemption_parsing():
    lines = ["x = 1  # lint: mirror(g-1)",
             "# lint: mirror(g-2)",
             "y = 2",
             "# lint: exempt(stats-columns, S_A S_B): because",
             "# lint: exempt(stats-columns, S_C)"]
    markers = parse_markers(lines)
    assert [(m.group, m.line) for m in markers] == [("g-1", 1),
                                                    ("g-2", 3)]
    exs = parse_exemptions(lines)
    assert [(e.check, e.tokens, e.reason) for e in exs] \
        == [("stats-columns", ("S_A", "S_B"), "because"),
            ("stats-columns", ("S_C",), "")]


def test_normalizer_separates_target_namespace():
    import ast

    def norm(src):
        stmt = ast.parse(src).body[0]
        return normalize_stmt(stmt, preserved={"jnp"})

    # carry-style rebinding vs fresh binding: identical
    assert norm("x = x.at[i].set(v)") == norm("y = x.at[i].set(v)")
    # a real operand change is not erased by the renaming
    assert norm("x = a + b") != norm("x = a - b")


# --------------------------------------------------- real tree is clean
def test_real_tree_all_passes_clean():
    results = run_all()
    rendered = [f.render() for fs in results.values() for f in fs]
    assert rendered == []
