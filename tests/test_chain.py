"""Switch chains with per-switch persistent buffers (pooling topologies).

Covers the acceptance properties of the chain promotion:
  (a) depth-1 results are bit-exact against the pre-chain engine — both
      standalone (the chain code is skipped at trace time) and as cells
      inside a mixed-depth grid (the per-field chain selects reduce to
      the identity), the PR 4 legacy-compat guard style;
  (b) a mixed {workload x scheme x depth 1..4 x policy} sweep compiles
      as ONE XLA program (depth, per-hop capacities and policies are
      traced);
  (c) the fig1 depth sweep emits the right series shapes — NoPB at
      every depth (0 = direct attach included), PB schemes only at
      depth >= 1;
  (d) per-hop stats rows follow the PR 3 NaN convention: a hop that saw
      zero traffic has NaN mean forward latency (never 0.0) and the
      figure scripts skip it;
  (e) ``pbe_per_hop`` construction-time validation.
"""
import math

import pytest

from conftest import TINY_BUCKET
from repro.core import (AllocPolicy, PBPolicy, PCSConfig, Scheme,
                        make_trace, simulate, simulate_grid)
from repro.core.engine import compile_count

COUNT_FIELDS = ("persists", "pm_reads", "read_hits", "coalesces",
                "pm_writes", "pi_detours", "victim_drains",
                "acked_persists", "durable_persists", "recovery_entries")
FLOAT_FIELDS = ("runtime_ns", "persist_lat_ns", "read_lat_ns", "stall_ns",
                "recovery_ns")


def _assert_bit_exact(a, b, label):
    for f in COUNT_FIELDS:
        assert getattr(a, f) == getattr(b, f), (label, f)
    for f in FLOAT_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if math.isnan(va) and math.isnan(vb):
            continue
        assert va == vb, (label, f, va, vb)


@pytest.fixture(scope="module")
def chain_trace():
    return make_trace("radiosity", persist_budget=150)


# ---------------------------------------------------------------------------
# (a) depth-1 legacy-compat: bit-exact inside a mixed-depth grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", [Scheme.NOPB, Scheme.PB, Scheme.PB_RF])
def test_depth1_bit_exact_inside_mixed_depth_grid(chain_trace, scheme):
    """A depth-1 cell inside a grid that allocates deep-hop rows must
    reproduce its standalone (chain-free program) result bit-exactly:
    the chain promotion may not perturb single-switch behaviour."""
    cfg = PCSConfig(scheme=scheme)
    ref = simulate(chain_trace, cfg, bucket=TINY_BUCKET)
    cells = simulate_grid(
        [chain_trace],
        [cfg, PCSConfig(scheme=Scheme.PB_RF, n_switches=4)],
        bucket=TINY_BUCKET)[0]
    _assert_bit_exact(cells[0], ref, scheme.name)


def test_depth1_crash_cell_bit_exact_in_mixed_depth_grid(chain_trace):
    """Same guard under a crash point (the durability snapshot rides the
    chain-aware recovery pass)."""
    t_end = simulate(chain_trace, PCSConfig(scheme=Scheme.PB_RF),
                     bucket=TINY_BUCKET).runtime_ns
    cfg = PCSConfig(scheme=Scheme.PB_RF).with_crash(0.4 * t_end)
    ref = simulate(chain_trace, cfg, bucket=TINY_BUCKET, track_addrs=8)
    cells = simulate_grid(
        [chain_trace],
        [cfg, PCSConfig(scheme=Scheme.PB, n_switches=3).with_crash(
            0.4 * t_end)],
        bucket=TINY_BUCKET, track_addrs=8)[0]
    _assert_bit_exact(cells[0], ref, "crash")
    assert (cells[0].durable_ver == ref.durable_ver).all()


# ---------------------------------------------------------------------------
# (b) one-program mixed {workload x scheme x depth x policy} sweep
# ---------------------------------------------------------------------------

def test_mixed_depth_policy_sweep_single_compile(chain_trace):
    tr2 = make_trace("raytrace", persist_budget=150)
    pol = PBPolicy(alloc=AllocPolicy(victim="weighted"))
    configs = []
    for scheme in (Scheme.PB, Scheme.PB_RF):
        for d in (1, 2, 3, 4):
            configs.append(PCSConfig(scheme=scheme, n_switches=d))
            configs.append(PCSConfig(scheme=scheme, n_switches=d,
                                     policy=pol))
    configs.append(PCSConfig(scheme=Scheme.NOPB, n_switches=2))
    c0 = compile_count()
    cells = simulate_grid([chain_trace, tr2], configs, bucket=TINY_BUCKET)
    assert compile_count() - c0 == 1, (
        "a mixed {workload x scheme x depth x policy} sweep must lower "
        "to ONE XLA program")
    for row in cells:
        for cfg, r in zip(configs, row):
            assert r.persists > 0, cfg
            if cfg.scheme != Scheme.NOPB:
                assert r.n_hops == cfg.n_switches
                assert len(r.hop_results()) == cfg.n_switches


# ---------------------------------------------------------------------------
# (c) fig1 series shapes: NoPB at every depth, PB only at depth >= 1
# ---------------------------------------------------------------------------

def test_fig1_depth_sweep_series_shapes():
    from benchmarks.fig1_switch_depth import DEPTHS, plan

    labels, configs = plan()
    nopb = [(n, c) for (k, n, _), c in zip(labels, configs) if k == "nopb"]
    pb = [(k, n) for (k, n, _), c in zip(labels, configs) if k != "nopb"]
    # NoPB must appear at EVERY depth, 0 (direct attach) included
    assert [n for n, _ in nopb] == list(DEPTHS)
    assert all(c.scheme == Scheme.NOPB for _, c in nopb)
    # PB schemes only where a switch exists to host the buffer
    assert all(n >= 1 for _, n in pb)
    for key in ("pb", "pb_rf"):
        assert sorted(n for k, n in pb if k == key) == [
            n for n in DEPTHS if n >= 1]


def test_fig1_rows_cover_every_depth_and_skip_nan_hops(monkeypatch):
    """End-to-end shape regression on the emitted rows: one latency row
    per (scheme, depth) with NoPB at every depth, and no NaN per-hop
    row ever emitted."""
    from benchmarks import _shared, fig1_switch_depth

    monkeypatch.setattr(_shared, "SMOKE", True, raising=False)
    rows = fig1_switch_depth.run(depths=(0, 1, 2))
    names = [r[0] for r in rows]
    for n in (0, 1, 2):
        assert f"fig1_nopb_n{n}" in names
    for key in ("pb", "pb_rf"):
        assert f"fig1_{key}_n0" not in names
        for n in (1, 2):
            assert f"fig1_{key}_n{n}" in names
            # crashed replicas attribute survivors to each hop
            assert f"fig1_recov_{key}_n{n}_h1" in names
    for name, value, _ in rows:
        assert not (isinstance(value, float) and math.isnan(value)), name


# ---------------------------------------------------------------------------
# (d) NaN convention for per-hop rows (zero-traffic deep hops)
# ---------------------------------------------------------------------------

def test_deep_hops_with_zero_traffic_report_nan_not_zero(chain_trace):
    """A chain deep enough that traffic never reaches its tail: the
    per-hop mean forward latency is NaN (no traffic has no latency,
    not an infinitely fast one), and counts are 0."""
    # PB_RF with a roomy hop 1 under a light load: the drain-down never
    # triggers, so nothing is ever forwarded below hop 1
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_switches=3,
                    pbe_per_hop=(256, 4, 4))
    r = simulate(make_trace("volrend_npl", persist_budget=40), cfg,
                 bucket=TINY_BUCKET)
    hops = r.hop_results()
    assert len(hops) == 3
    assert hops[0]["commits"] > 0 and not math.isnan(hops[0]["fwd_lat_ns"])
    for h in hops[1:]:
        assert h["commits"] == 0, h
        assert math.isnan(h["fwd_lat_ns"]), (
            "zero-traffic hop must report NaN, not a 0.0 ns mean")


# ---------------------------------------------------------------------------
# (e) construction-time validation
# ---------------------------------------------------------------------------

def test_pbe_per_hop_arity_must_match_depth():
    with pytest.raises(ValueError, match="one per switch"):
        PCSConfig(scheme=Scheme.PB, n_switches=2, pbe_per_hop=(4, 4, 4))


def test_pbe_per_hop_entries_positive():
    with pytest.raises(ValueError, match=">= 1"):
        PCSConfig(scheme=Scheme.PB, n_switches=2, pbe_per_hop=(4, 0))


def test_pbe_per_hop_rejected_for_nopb():
    with pytest.raises(ValueError, match="NOPB"):
        PCSConfig(scheme=Scheme.NOPB, n_switches=2, pbe_per_hop=(4, 4))


def test_pbe_per_hop_syncs_hop1_capacity():
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_switches=3, pbe_per_hop=(8, 4, 2))
    assert cfg.n_pbe == 8
    assert cfg.hop_pbes == (8, 4, 2)
    assert cfg.max_hop_pbe == 8
    # defaulting: every hop inherits n_pbe
    assert PCSConfig(scheme=Scheme.PB, n_switches=2, n_pbe=4).hop_pbes \
        == (4, 4)
