"""Cross-validation: the timed engine against the untimed oracle.

On single-core random traces whose compute gaps exceed the worst-case
drain round trip ("prompt-ack regime"), the timed engine's event counts
must match the untimed state machine of ``core.semantics`` exactly, for
all three schemes: every drain scheduled by one op completes before the
next op, which is precisely the oracle's semantics when every pending PM
ack is delivered between ops.

This is the drift guard between the three policy copies: the traced
policy (``engine.policy.drain_threshold_preset``), its scalar twin
(``engine.policy.rf_drain_count``, used by the oracle) and the LRU /
coalescing rules shared by both layers.
"""
import random

import numpy as np
import pytest

from repro.core import Op, PCSConfig, Scheme, Trace
from repro.core.engine import simulate
from repro.core.semantics import EventKind, PersistentBuffer

# gap >> worst-case drain ack (PBC + burst of n_pbe bank-serialized
# writes + links): keeps the machine uncongested between ops.
GAP_NS = 50_000.0


def _random_ops(seed, n_ops=160, n_addrs=12, p_persist=0.55):
    rng = random.Random(seed)
    return [(Op.PERSIST if rng.random() < p_persist else Op.PM_READ,
             rng.randrange(n_addrs)) for _ in range(n_ops)]


def _as_trace(op_list):
    ops = np.array([[int(o) for o, _ in op_list]], np.int32)
    addrs = np.array([[a for _, a in op_list]], np.int32)
    gaps = np.full(ops.shape, GAP_NS, np.float32)
    lengths = np.array([ops.shape[1]], np.int32)
    return Trace(ops=ops, addrs=addrs, gaps=gaps, lengths=lengths,
                 name="xval")


def _oracle_counts(op_list, scheme, n_pbe):
    """Drive the oracle, delivering every pending PM ack between ops."""
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=n_pbe))
    pending = []
    victim_drains = 0
    for op, addr in op_list:
        if op == Op.PERSIST:
            events = pb.persist(addr, f"v@{addr}")
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
            victim_drains += sum(
                1 for e in events if e.kind == EventKind.STALLED)
        else:
            pb.read(addr)
        # prompt-ack regime: all in-flight drains complete before the
        # next op (FIFO channel order)
        while pending:
            a, v = pending.pop(0)
            events = pb.pm_ack(a, v)
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
    return dict(
        persists=pb.stats["persists"],
        coalesces=pb.stats["coalesces"],
        read_hits=pb.stats["read_hits"],
        pm_reads=pb.stats["read_hits"] + pb.stats["read_misses"],
        pm_writes=(pb.pm.writes_applied if scheme == Scheme.NOPB
                   else pb.stats["drains"]),
        victim_drains=victim_drains,
    )


@pytest.mark.parametrize("scheme", [Scheme.NOPB, Scheme.PB, Scheme.PB_RF])
@pytest.mark.parametrize("seed,n_pbe", [(0, 8), (1, 8), (2, 4), (3, 16)])
def test_engine_counts_match_oracle(scheme, seed, n_pbe):
    op_list = _random_ops(seed)
    res = simulate(_as_trace(op_list), PCSConfig(scheme=scheme, n_pbe=n_pbe),
                   bucket=256)
    want = _oracle_counts(op_list, scheme, n_pbe)
    got = dict(persists=res.persists, coalesces=res.coalesces,
               read_hits=res.read_hits, pm_reads=res.pm_reads,
               pm_writes=res.pm_writes, victim_drains=res.victim_drains)
    assert got == want, (scheme.name, seed, n_pbe)


@pytest.mark.parametrize("seed", [5, 6])
def test_engine_matches_oracle_hot_set(seed):
    """High write locality (the radiosity shape): coalescing and read
    forwarding dominate; counts must still agree exactly."""
    rng = random.Random(seed)
    op_list = [(Op.PERSIST if rng.random() < 0.7 else Op.PM_READ,
                rng.randrange(4)) for _ in range(200)]
    for scheme in (Scheme.PB, Scheme.PB_RF):
        res = simulate(_as_trace(op_list), PCSConfig(scheme=scheme, n_pbe=8),
                       bucket=256)
        want = _oracle_counts(op_list, scheme, 8)
        assert res.coalesces == want["coalesces"]
        assert res.read_hits == want["read_hits"]
        assert res.pm_writes == want["pm_writes"]
        assert res.victim_drains == want["victim_drains"] == 0
    # PB_RF on a 4-line hot set actually coalesces; the oracle agrees
    res_rf = simulate(_as_trace(op_list), PCSConfig(scheme=Scheme.PB_RF,
                                                    n_pbe=8), bucket=256)
    assert res_rf.coalesces > 0 and res_rf.read_hits > 0
