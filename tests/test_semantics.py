"""Property tests: the paper's three correctness criteria (Section IV-A)
hold for the PB/PBC/PBCS state machine under arbitrary schedules."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PCSConfig, Scheme
from repro.core.semantics import EventKind, PersistentBuffer

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]


def run_schedule(scheme, n_pbe, ops, ack_order):
    """Drive the buffer with a schedule; return (pb, acked, reads)."""
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=n_pbe))
    acked = {}
    pending = []
    reads = []
    version_of_payload = {}
    ai = 0
    for op, addr in ops:
        if op == "persist":
            payload = f"{addr}@{len(version_of_payload)}"
            for e in pb.persist(addr, payload):
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    acked[e.addr] = max(acked.get(e.addr, -1), e.version)
                    version_of_payload[(e.addr, e.version)] = payload
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
        elif op == "ack" and pending:
            i = ack_order[ai % len(ack_order)] % len(pending)
            ai += 1
            a, v = pending.pop(i)
            for e in pb.pm_ack(a, v):
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    acked[e.addr] = max(acked.get(e.addr, -1), e.version)
        else:
            data, ev = pb.read(addr)
            reads.append((addr, data, ev))
        pb.check_invariants()
    return pb, acked, reads


@settings(max_examples=60, deadline=None)
@given(
    scheme=st.sampled_from(SCHEMES),
    n_pbe=st.integers(2, 8),
    ops=st.lists(st.tuples(st.sampled_from(["persist", "ack", "read"]),
                           st.integers(0, 5)), min_size=1, max_size=120),
    ack_order=st.lists(st.integers(0, 31), min_size=1, max_size=32),
)
def test_crash_consistency_and_write_order(scheme, n_pbe, ops, ack_order):
    pb, acked, _ = run_schedule(scheme, n_pbe, ops, ack_order)
    # crash at an arbitrary point, then recover: no acked version is lost
    pb.crash()
    pb.recover()
    for addr, ver in acked.items():
        rec = pb.pm.read(addr)
        assert rec is not None, f"acked addr {addr} lost"
        assert rec[0] >= ver, f"addr {addr}: pm={rec[0]} < acked={ver}"


@settings(max_examples=60, deadline=None)
@given(
    scheme=st.sampled_from([Scheme.PB, Scheme.PB_RF]),
    n_pbe=st.integers(2, 8),
    ops=st.lists(st.tuples(st.sampled_from(["persist", "ack", "read"]),
                           st.integers(0, 3)), min_size=1, max_size=120),
    ack_order=st.lists(st.integers(0, 31), min_size=1, max_size=32),
)
def test_write_read_order(scheme, n_pbe, ops, ack_order):
    """A read must observe the newest acked version (buffer or PM)."""
    pb, acked, reads = run_schedule(scheme, n_pbe, ops, ack_order)
    # replay: after the final state, reads of every acked address return
    # the newest acked payload from somewhere in the persistent domain
    for addr, ver in acked.items():
        data, ev = pb.read(addr)
        assert data is not None
        assert data == f"{addr}@" + data.split("@")[1]  # well-formed
        # version check: the entry served is >= newest acked
        assert ev.version >= ver or ev.kind == EventKind.READ_FROM_PM


def test_nopb_is_write_through():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.NOPB, n_pbe=4))
    for i in range(10):
        pb.persist(i % 3, f"v{i}")
    assert pb.pm.writes_applied == 10
    assert all(e.state.name == "EMPTY" for e in pb.entries)


def test_coalescing_only_in_rf():
    for scheme, expect in [(Scheme.PB, 0), (Scheme.PB_RF, 1)]:
        pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=4))
        pb.persist(1, "a")
        evs = pb.persist(1, "b")
        coal = [e for e in evs if e.kind == EventKind.COALESCED]
        assert len(coal) == expect, scheme


def test_rf_keeps_entries_for_forwarding():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB_RF, n_pbe=8))
    pb.persist(1, "a")
    data, ev = pb.read(1)
    assert ev.kind == EventKind.READ_FROM_PB and data == "a"


def test_pb_drains_immediately():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB, n_pbe=8))
    evs = pb.persist(1, "a")
    assert any(e.kind == EventKind.DRAIN_SENT for e in evs)


def test_stall_when_all_draining():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB, n_pbe=2))
    pb.persist(1, "a")
    pb.persist(2, "b")
    evs = pb.persist(3, "c")  # both entries in Drain, no Empty
    assert any(e.kind == EventKind.STALLED for e in evs)
    # ack frees an entry and retries the stalled write
    evs = pb.pm_ack(1, 1)
    assert any(e.kind == EventKind.PERSIST_ACK and e.addr == 3 for e in evs)


@settings(max_examples=40, deadline=None)
@given(
    n_pbe=st.integers(4, 16),
    addrs=st.lists(st.integers(0, 30), min_size=1, max_size=200),
)
def test_rf_threshold_preset_invariant(n_pbe, addrs):
    """After any persist under PB_RF, the Dirty count never exceeds the
    drain threshold (the drain-down runs to the preset, Section V-D1)."""
    from repro.core.params import PBEState
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_pbe=n_pbe)
    pb = PersistentBuffer(cfg)
    for i, a in enumerate(addrs):
        evs = pb.persist(a, f"v{i}")
        dirty = sum(1 for e in pb.entries if e.state == PBEState.DIRTY)
        assert dirty <= max(cfg.threshold_count, cfg.preset_count + 1), (
            dirty, cfg.threshold_count)
        pb.check_invariants()


@settings(max_examples=40, deadline=None)
@given(
    scheme=st.sampled_from([Scheme.PB, Scheme.PB_RF]),
    ops=st.lists(st.tuples(st.booleans(), st.integers(0, 6)),
                 min_size=1, max_size=150),
)
def test_reads_never_return_stale_after_ack(scheme, ops):
    """Write-read order: a read after an acked persist returns that
    version's payload or newer, never an older one."""
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=4))
    newest = {}
    pending = []
    for is_persist, addr in ops:
        if is_persist:
            for e in pb.persist(addr, None):
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    newest[e.addr] = max(newest.get(e.addr, -1), e.version)
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
        elif pending:
            a, v = pending.pop(0)   # in-order acks (FIFO channel)
            for e in pb.pm_ack(a, v):
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    newest[e.addr] = max(newest.get(e.addr, -1), e.version)
        if addr in newest:
            _, ev = pb.read(addr)
            assert ev.version >= newest[addr], (
                scheme, addr, ev.version, newest[addr])
