"""Deterministic tests of the PB/PBC/PBCS state machine (Section IV-A).

The hypothesis-based property tests live in tests/test_semantics_props.py
and are skipped when the optional ``hypothesis`` dependency is absent;
this module keeps a deterministic random-schedule fallback so the three
correctness criteria are always exercised by the tier-1 suite.
"""
import random

import pytest

from repro.core import PCSConfig, Scheme
from repro.core.semantics import EventKind, PersistentBuffer

from _semantics_driver import run_schedule

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]


def _random_schedule(rng, n_ops=120, n_addrs=6):
    ops = [(rng.choice(["persist", "ack", "read"]), rng.randrange(n_addrs))
           for _ in range(n_ops)]
    ack_order = [rng.randrange(32) for _ in range(16)]
    return ops, ack_order


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_crash_consistency_random_schedules(scheme, seed):
    """Deterministic fallback for the crash-consistency property: drive
    the machine with a seeded random schedule, crash, recover — no acked
    version may be lost."""
    rng = random.Random(1000 * int(scheme) + seed)
    ops, ack_order = _random_schedule(rng)
    pb, acked, _ = run_schedule(scheme, n_pbe=2 + seed, ops=ops,
                                ack_order=ack_order)
    pb.crash()
    pb.recover()
    for addr, ver in acked.items():
        rec = pb.pm.read(addr)
        assert rec is not None, f"acked addr {addr} lost"
        assert rec[0] >= ver, f"addr {addr}: pm={rec[0]} < acked={ver}"


@pytest.mark.parametrize("scheme", [Scheme.PB, Scheme.PB_RF])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_write_read_order_random_schedules(scheme, seed):
    """Deterministic fallback for write-read order: after the run, reads
    of every acked address observe the newest acked version or newer."""
    rng = random.Random(2000 * int(scheme) + seed)
    ops, ack_order = _random_schedule(rng, n_addrs=4)
    pb, acked, _ = run_schedule(scheme, n_pbe=2 + seed, ops=ops,
                                ack_order=ack_order)
    for addr, ver in acked.items():
        data, ev = pb.read(addr)
        assert data is not None
        assert ev.version >= ver or ev.kind == EventKind.READ_FROM_PM


def test_nopb_is_write_through():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.NOPB, n_pbe=4))
    for i in range(10):
        pb.persist(i % 3, f"v{i}")
    assert pb.pm.writes_applied == 10
    assert all(e.state.name == "EMPTY" for e in pb.entries)


def test_coalescing_only_in_rf():
    for scheme, expect in [(Scheme.PB, 0), (Scheme.PB_RF, 1)]:
        pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=4))
        pb.persist(1, "a")
        evs = pb.persist(1, "b")
        coal = [e for e in evs if e.kind == EventKind.COALESCED]
        assert len(coal) == expect, scheme


def test_rf_keeps_entries_for_forwarding():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB_RF, n_pbe=8))
    pb.persist(1, "a")
    data, ev = pb.read(1)
    assert ev.kind == EventKind.READ_FROM_PB and data == "a"


def test_pb_drains_immediately():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB, n_pbe=8))
    evs = pb.persist(1, "a")
    assert any(e.kind == EventKind.DRAIN_SENT for e in evs)


def test_stall_when_all_draining():
    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB, n_pbe=2))
    pb.persist(1, "a")
    pb.persist(2, "b")
    evs = pb.persist(3, "c")  # both entries in Drain, no Empty
    assert any(e.kind == EventKind.STALLED for e in evs)
    # ack frees an entry and retries the stalled write
    evs = pb.pm_ack(1, 1)
    assert any(e.kind == EventKind.PERSIST_ACK and e.addr == 3 for e in evs)


def test_rf_threshold_preset_invariant_deterministic():
    """After any persist under PB_RF, the Dirty count never exceeds the
    drain threshold (the drain-down runs to the preset, Section V-D1)."""
    from repro.core.params import PBEState
    rng = random.Random(7)
    for n_pbe in (4, 8, 16):
        cfg = PCSConfig(scheme=Scheme.PB_RF, n_pbe=n_pbe)
        pb = PersistentBuffer(cfg)
        for i in range(200):
            pb.persist(rng.randrange(30), f"v{i}")
            dirty = sum(1 for e in pb.entries if e.state == PBEState.DIRTY)
            assert dirty <= max(cfg.threshold_count, cfg.preset_count + 1), (
                dirty, cfg.threshold_count)
            pb.check_invariants()


def test_rf_keep_one_free_drains_early():
    """The shared keep-one-free heuristic (engine.policy.rf_drain_count):
    when the Empty pool is exhausted, the PB_RF policy drains LRU Dirty
    entries pre-emptively even below the threshold fill."""
    from repro.core.engine.policy import (RF_EMPTY_SLACK, RF_LOW_WATER_DRAINS,
                                          rf_drain_count)
    from repro.core.params import PBEState
    # below threshold but out of Empty slots -> the low-water path fires
    assert rf_drain_count(dirty=3, empty=RF_EMPTY_SLACK, threshold=7,
                          preset=4) == min(RF_LOW_WATER_DRAINS, 3)
    # above threshold -> drain down to the preset
    assert rf_drain_count(dirty=7, empty=5, threshold=7, preset=4) == 3
    # plenty of room -> no drains
    assert rf_drain_count(dirty=3, empty=5, threshold=7, preset=4) == 0

    pb = PersistentBuffer(PCSConfig(scheme=Scheme.PB_RF, n_pbe=4))
    for a in (0, 1, 2):   # third persist leaves <= 1 Empty slot
        pb.persist(a, "x")
    assert sum(1 for e in pb.entries if e.state == PBEState.DRAIN) >= 1


@pytest.mark.parametrize("scheme", [Scheme.PB, Scheme.PB_RF])
@pytest.mark.parametrize("seed", [11, 12])
def test_snapshot_durable_predicts_recovery(scheme, seed):
    """The non-mutating durable snapshot equals what crash+recover
    actually leaves in PM (per-address newest durable version)."""
    rng = random.Random(seed)
    ops = [(rng.choice(["persist", "ack", "read"]), rng.randrange(5))
           for _ in range(120)]
    pb, _acked, _reads = run_schedule(scheme, 4, ops, [3, 0, 2, 1])
    snap = {a: rec[0] for a, rec in pb.snapshot_durable().items()}
    pb.crash()
    pb.recover()
    assert {a: rec[0] for a, rec in pb.pm.store.items()} == snap
