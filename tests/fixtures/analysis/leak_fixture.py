"""f32-leak fixture: demotes an f64 product to f32 at line 8."""
import jax.numpy as jnp


def leak(x):
    # the demotion the dtype pass must flag
    y = x * 2.0
    return y.astype(jnp.float32)
