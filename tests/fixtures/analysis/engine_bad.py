"""Engine side of the twin fixture: consumes only ``alpha``."""


def run(pol):
    return pol.alpha + 1
