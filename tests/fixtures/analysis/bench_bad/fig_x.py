"""Emits one full sweep, one unregistered key, one partial sweep."""

sweep_metrics = {}


def run():
    sweep_metrics.update(
        good_sweep_wall_s=1.0,
        good_sweep_compile_s=0.1,
        good_sweep_compiles=1,
        good_sweep_cells=3,
        good_sweep_macro_hit=0.5,
        rogue_sweep_compiles=1,
        partial_sweep_wall_s=2.0,
    )
