"""Sweep-registry fixture."""

SWEEPS = (
    "good_sweep",
    "ghost_sweep",
    "partial_sweep",
)
