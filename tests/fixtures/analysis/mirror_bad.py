"""Seeded mirror-pass violations (AST-parsed only, never imported)."""
import jax.numpy as jnp


def site_a(x, y):
    out = jnp.where(x > 1.0, x - y, 0.0)  # lint: mirror(pair)
    return out


def site_b(p, q):
    ret = jnp.where(p > 1.0, p + q, 0.0)  # lint: mirror(pair)
    return ret


def site_c(a, b, st):
    val = st.acc.at[a].add(b)  # lint: mirror(same)
    return val


def site_d(acc_cur, i, j):
    acc_cur = acc_cur.at[i].add(j)  # lint: mirror(same)
    return acc_cur


def mystery_site(x):
    y = x + 1  # lint: mirror(mystery)
    return y


def fam_a(acc):
    return acc + S_ONE + S_TWO


def fam_b(acc):
    # lint: exempt(stats-columns, S_TWO): fixture-only column
    return acc + S_ONE


def fam_c(acc):
    # lint: exempt(stats-columns, S_TWO)
    return acc + S_ONE


# lint: mirror(orphan)
