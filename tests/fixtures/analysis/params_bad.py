"""Declaration-side retrace fixture: a params-like policy dataclass
that grew a schedule knob (``quota_schedule``) without registering it
in SWEEPABLE_FIELDS or STATIC_FIELDS — ``check_registered_fields``
must pin the exact field line with ``retrace-unregistered-field``."""
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BadPolicy:
    threshold: float = 0.75
    quota_schedule: Optional[Tuple[float, ...]] = None
