"""Sweep-registry fixture: the fabric sweep is NOT registered."""

SWEEPS = (
    "chain_sweep",
)
