"""A fabric figure whose sweep was never registered: it emits the full
fabric_sweep_* telemetry (all five suffixes, abort counters included)
but benchmarks/_sweeps.py-style registration is missing, so
check_compiles would never guard its compile count — the linter must
flag exactly this."""

sweep_metrics = {}


def run():
    sweep_metrics.update(
        chain_sweep_wall_s=1.0,
        chain_sweep_compile_s=0.2,
        chain_sweep_compiles=1,
        chain_sweep_cells=5,
        chain_sweep_macro_hit=0.4,
        fabric_sweep_wall_s=2.0,
        fabric_sweep_compile_s=0.3,
        fabric_sweep_compiles=1,
        fabric_sweep_cells=52,
        fabric_sweep_macro_hit=0.3,
        fabric_sweep_macro_aborts={"window": 0, "fabric": 7},
    )
