"""Oracle side of the twin fixture: consumes only ``beta``."""


def run(pol):
    return pol.beta + 1
