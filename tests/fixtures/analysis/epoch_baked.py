"""Operand-side retrace fixture: an epoch-scheduled lowering whose
step consumes only epoch 0's row (``sc["quota"][0]`` with a *static*
index) and never reads the shared ``epoch_bounds`` vector — the
schedule is baked to its first epoch, so whole-program DCE must flag
the boundary operand dead (``retrace-baked-static``)."""


def step(sc):
    return sc["quota"][0] * 2.0 + sc["crash_at"]
