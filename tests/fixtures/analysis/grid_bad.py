"""Donation fixture: missing buffers and an undonated jit wrapper."""
import functools

import jax

_DONATED = ("ops", "addrs")


@functools.partial(jax.jit, static_argnames=("n",))
def run(ops, addrs, gaps, mlen, n):
    return ops
