"""Per-kernel allclose sweeps (shape x dtype) against the ref oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, ssd_scan, tat_lookup
from repro.kernels import ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("r,n", [(256, 16), (512, 64), (1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.int32])
def test_tat_lookup_sweep(r, n, dtype):
    req = jnp.asarray(RNG.integers(0, n * 2, r), dtype)
    tat = jnp.asarray(RNG.integers(0, n * 2, n), dtype)
    st = jnp.asarray(RNG.integers(0, 3, n), jnp.int32)
    i1, s1 = tat_lookup(req, tat, st)
    i2, s2 = ref.tat_lookup_ref(req, tat, st)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(s1, s2)


def test_tat_lookup_empty_never_matches():
    req = jnp.asarray([7, 7], jnp.int32)
    tat = jnp.asarray([7, 7, 7, 7], jnp.int32)
    st = jnp.asarray([0, 0, 0, 0], jnp.int32)  # all Empty
    idx, s = ref.tat_lookup_ref(req, tat, st)
    assert (idx == -1).all() and (s == 0).all()


@pytest.mark.parametrize("b,h,s,d", [(2, 2, 256, 64), (1, 4, 128, 128),
                                     (1, 1, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.slow
def test_flash_attention_sweep(b, h, s, d, dtype, window):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    o1 = flash_attention(q, k, v, causal=True, window=window,
                         block_q=128, block_k=128)
    o2 = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(
        o1.astype(jnp.float32) - o2.astype(jnp.float32)))) < tol


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=False)
    o2 = ref.flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 3, 64, 128, 128), (1, 128, 2, 32, 64, 64),
    (2, 512, 1, 64, 128, 128), (1, 256, 4, 64, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, n)), dtype)
    C = jnp.asarray(RNG.standard_normal((b, s, n)), dtype)
    y1, f1 = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y2, f2 = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    assert float(jnp.max(jnp.abs(
        y1.astype(jnp.float32) - y2.astype(jnp.float32)))) < tol
    assert float(jnp.max(jnp.abs(f1 - f2))) < tol


def test_ssd_kernel_matches_sequential():
    """Transitively: kernel == chunked ref == sequential recurrence."""
    from repro.models.ssm import ssd_decode_step
    b, s, h, p, n = 1, 128, 2, 16, 32
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 1.5, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], state)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    y_k, f_k = ssd_scan(x, dt, A, B, C, chunk=64)
    assert float(jnp.max(jnp.abs(y_k - y_seq))) < 1e-3
    assert float(jnp.max(jnp.abs(f_k - state))) < 1e-3
