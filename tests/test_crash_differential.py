"""Differential crash-point conformance: timed engine vs untimed oracle.

Every cell fuzzes a multi-core persist/read/barrier interleaving
(``core.traces.fuzz_trace``), crashes the timed engine at a slot
boundary (``crash_at_ns`` traced config scalar) and the oracle after
replaying the same slots, and asserts the durable-state agreement the
paper's correctness argument requires: identical per-address durable
versions after recovery, no acked version lost, no unacked version
resurrected, and read forwarding never serving a value recovery would
discard (tests/_crash_driver.py).

The deterministic matrix — >= 200 (trace, scheme, crash-point) cells —
always runs, through ONE compiled simulate_grid program (crash time and
scheme are traced, so the whole matrix is a single XLA program).  When
``hypothesis`` is installed it additionally drives randomized cells
(same guard pattern as tests/test_semantics_props.py); without it a
seeded parametrized fallback covers the same space.

``make test-fuzz`` raises the budgets via CRASH_FUZZ_SEEDS /
CRASH_FUZZ_EXAMPLES.
"""
import os

import pytest

from _crash_driver import assert_cell_matches, oracle_replay
from repro.core import (AllocPolicy, DrainPolicy, PBPolicy, PCSConfig,
                        Scheme, fuzz_crash_ns, fuzz_trace, tenant_ids)
from repro.core.engine import compile_count, simulate, simulate_grid

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # deterministic fallback below
    HAVE_HYPOTHESIS = False

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]
N_ADDRS = 6
N_SLOTS = 50
N_CORES = 3
BUCKET = 128
CRASH_SLOTS = (0, 7, 13, 21, 29, 37, 44, N_SLOTS)
PBES = (2, 4, 8)         # traced, cycles across crash points
N_SEEDS = int(os.environ.get("CRASH_FUZZ_SEEDS", "9"))
N_EXAMPLES = int(os.environ.get("CRASH_FUZZ_EXAMPLES", "25"))


def test_differential_matrix_one_compile():
    """>= 200 fuzzed cells, engine side in ONE compiled grid program."""
    seeds = list(range(N_SEEDS))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=N_CORES, n_slots=N_SLOTS, n_addrs=N_ADDRS)
        for s in seeds])
    plan = [(scheme, k, PBES[ki % len(PBES)])
            for scheme in SCHEMES for ki, k in enumerate(CRASH_SLOTS)]
    configs = [PCSConfig(scheme=s, n_pbe=p).with_crash(fuzz_crash_ns(k))
               for s, k, p in plan]
    n_cells = len(seeds) * len(configs)
    assert n_cells >= 200, n_cells

    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the whole {trace x scheme x crash-point} matrix must be one "
        "XLA program")
    for i, sched in enumerate(scheds):
        for j, (scheme, k, n_pbe) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=(seeds[i], scheme.name, k, n_pbe))


def test_differential_matrix_multi_tenant_one_compile():
    """T=2 tenants sharing the PB/PBC/PM: durable state AND per-tenant
    accounting must match the tenant-tagged oracle at every crash point,
    with the whole {trace x scheme x crash-point} matrix one program."""
    n_tenants, n_cores = 2, 4
    seeds = list(range(4))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants)
        for s in seeds])
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = [(scheme, k, PBES[ki % len(PBES)])
            for scheme in SCHEMES for ki, k in enumerate(crash_slots)]
    configs = [PCSConfig(scheme=s, n_pbe=p, n_cores=n_cores,
                         n_tenants=n_tenants).with_crash(fuzz_crash_ns(k))
               for s, k, p in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the multi-tenant matrix must be one XLA program")
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, n_pbe) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=("T2", seeds[i], scheme.name, k,
                                       n_pbe))


def test_differential_matrix_quota_policies_one_compile():
    """Non-default QoS policies (per-tenant quotas, weighted victim
    selection, tenant-scoped drain-down) mixed with the default in ONE
    compiled grid: the engine must agree with the policy-aware oracle on
    the durable state, the per-tenant accounting AND the per-tenant
    surviving-entry attribution at every crash point."""
    n_tenants, n_cores = 2, 4
    seeds = list(range(4))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in seeds])
    # one policy per PBE capacity (quotas must sum <= n_pbe), mixed with
    # the default policy at the same capacity
    policies = {
        2: PBPolicy(alloc=AllocPolicy(tenant_quota=(1, 1))),
        4: PBPolicy(alloc=AllocPolicy(victim="weighted",
                                      tenant_quota=(1, 3))),
        8: PBPolicy(drain=DrainPolicy(per_tenant=True),
                    alloc=AllocPolicy(tenant_quota=(2, 5))),
    }
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = []
    for scheme in SCHEMES:
        for ki, k in enumerate(crash_slots):
            n_pbe = PBES[ki % len(PBES)]
            plan.append((scheme, k, n_pbe, policies[n_pbe]))
            plan.append((scheme, k, n_pbe, None))        # default, mixed
    configs = [PCSConfig(scheme=s, n_pbe=p, n_cores=n_cores,
                         n_tenants=n_tenants,
                         policy=pol).with_crash(fuzz_crash_ns(k))
               for s, k, p, pol in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the mixed {trace x scheme x crash-point x policy} matrix must "
        "be one XLA program")
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, n_pbe, pol) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants, policy=pol)
            assert_cell_matches(
                cells[i][j], oracle, N_ADDRS,
                label=("QOS", seeds[i], scheme.name, k, n_pbe,
                       "default" if pol is None else str(pol.alloc)))


def _one_cell(seed, scheme, crash_slot, n_pbe, p_persist=0.55):
    trace, sched = fuzz_trace(seed, n_cores=N_CORES, n_slots=N_SLOTS,
                              n_addrs=N_ADDRS, p_persist=p_persist)
    res = simulate(trace,
                   PCSConfig(scheme=scheme, n_pbe=n_pbe).with_crash(
                       fuzz_crash_ns(crash_slot)),
                   max_pbe=max(PBES), bucket=BUCKET, track_addrs=N_ADDRS)
    oracle = oracle_replay(sched, crash_slot, scheme, n_pbe)
    assert_cell_matches(res, oracle, N_ADDRS,
                        label=(seed, scheme.name, crash_slot, n_pbe))


if HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scheme=st.sampled_from(SCHEMES),
        crash_slot=st.integers(0, N_SLOTS),
        n_pbe=st.sampled_from(PBES),
        p_persist=st.floats(0.1, 0.9),
    )
    def test_differential_fuzz(seed, scheme, crash_slot, n_pbe, p_persist):
        _one_cell(seed, scheme, crash_slot, n_pbe, p_persist)

else:

    @pytest.mark.parametrize("case", range(N_EXAMPLES))
    def test_differential_fuzz(case):
        import random
        rng = random.Random(0xC0FFEE + case)
        _one_cell(seed=rng.randrange(2**31),
                  scheme=rng.choice(SCHEMES),
                  crash_slot=rng.randrange(N_SLOTS + 1),
                  n_pbe=rng.choice(PBES),
                  p_persist=rng.uniform(0.1, 0.9))
