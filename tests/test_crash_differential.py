"""Differential crash-point conformance: timed engine vs untimed oracle.

Every cell fuzzes a multi-core persist/read/barrier interleaving
(``core.traces.fuzz_trace``), crashes the timed engine at a slot
boundary (``crash_at_ns`` traced config scalar) and the oracle after
replaying the same slots, and asserts the durable-state agreement the
paper's correctness argument requires: identical per-address durable
versions after recovery, no acked version lost, no unacked version
resurrected, and read forwarding never serving a value recovery would
discard (tests/_crash_driver.py).

The deterministic matrix — >= 200 (trace, scheme, crash-point) cells —
always runs, through ONE compiled simulate_grid program (crash time and
scheme are traced, so the whole matrix is a single XLA program).  When
``hypothesis`` is installed it additionally drives randomized cells
(same guard pattern as tests/test_semantics_props.py); without it a
seeded parametrized fallback covers the same space.

The macro-stepped engine (``engine.macro``, on by default) is pinned
two ways: every matrix above already runs macro-enabled against the
untimed oracle, and a dedicated macro column re-runs fuzzed matrices
with ``macro=False`` and asserts *exact* SimResult equality — every
scalar, per-tenant row and per-hop row bit-identical, so a macro guard
that silently admits a non-straight-line window cannot hide behind the
oracle's coarser durable-state view.

``make test-fuzz`` raises the budgets via CRASH_FUZZ_SEEDS /
CRASH_FUZZ_EXAMPLES.
"""
import os

import numpy as np
import pytest

from _crash_driver import assert_cell_matches, oracle_replay
from repro.core import (AllocPolicy, DrainPolicy, FabricTopology, PBPolicy,
                        PCSConfig, Schedule, Scheme, fuzz_crash_ns,
                        fuzz_trace, leaf_placement, tenant_ids)
from repro.core.engine import compile_count, simulate, simulate_grid

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # deterministic fallback below
    HAVE_HYPOTHESIS = False

SCHEMES = [Scheme.NOPB, Scheme.PB, Scheme.PB_RF]
N_ADDRS = 6
N_SLOTS = 50
N_CORES = 3
BUCKET = 128
CRASH_SLOTS = (0, 7, 13, 21, 29, 37, 44, N_SLOTS)
PBES = (2, 4, 8)         # traced, cycles across crash points
N_SEEDS = int(os.environ.get("CRASH_FUZZ_SEEDS", "9"))
N_EXAMPLES = int(os.environ.get("CRASH_FUZZ_EXAMPLES", "25"))


def test_differential_matrix_one_compile():
    """>= 200 fuzzed cells, engine side in ONE compiled grid program."""
    seeds = list(range(N_SEEDS))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=N_CORES, n_slots=N_SLOTS, n_addrs=N_ADDRS)
        for s in seeds])
    plan = [(scheme, k, PBES[ki % len(PBES)])
            for scheme in SCHEMES for ki, k in enumerate(CRASH_SLOTS)]
    configs = [PCSConfig(scheme=s, n_pbe=p).with_crash(fuzz_crash_ns(k))
               for s, k, p in plan]
    n_cells = len(seeds) * len(configs)
    assert n_cells >= 200, n_cells

    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the whole {trace x scheme x crash-point} matrix must be one "
        "XLA program")
    for i, sched in enumerate(scheds):
        for j, (scheme, k, n_pbe) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=(seeds[i], scheme.name, k, n_pbe))


def test_differential_matrix_multi_tenant_one_compile():
    """T=2 tenants sharing the PB/PBC/PM: durable state AND per-tenant
    accounting must match the tenant-tagged oracle at every crash point,
    with the whole {trace x scheme x crash-point} matrix one program."""
    n_tenants, n_cores = 2, 4
    seeds = list(range(4))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants)
        for s in seeds])
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = [(scheme, k, PBES[ki % len(PBES)])
            for scheme in SCHEMES for ki, k in enumerate(crash_slots)]
    configs = [PCSConfig(scheme=s, n_pbe=p, n_cores=n_cores,
                         n_tenants=n_tenants).with_crash(fuzz_crash_ns(k))
               for s, k, p in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the multi-tenant matrix must be one XLA program")
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, n_pbe) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=("T2", seeds[i], scheme.name, k,
                                       n_pbe))


def test_differential_matrix_quota_policies_one_compile():
    """Non-default QoS policies (per-tenant quotas, weighted victim
    selection, tenant-scoped drain-down) mixed with the default in ONE
    compiled grid: the engine must agree with the policy-aware oracle on
    the durable state, the per-tenant accounting AND the per-tenant
    surviving-entry attribution at every crash point."""
    n_tenants, n_cores = 2, 4
    seeds = list(range(4))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in seeds])
    # one policy per PBE capacity (quotas must sum <= n_pbe), mixed with
    # the default policy at the same capacity
    policies = {
        2: PBPolicy(alloc=AllocPolicy(tenant_quota=(1, 1))),
        4: PBPolicy(alloc=AllocPolicy(victim="weighted",
                                      tenant_quota=(1, 3))),
        8: PBPolicy(drain=DrainPolicy(per_tenant=True),
                    alloc=AllocPolicy(tenant_quota=(2, 5))),
    }
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = []
    for scheme in SCHEMES:
        for ki, k in enumerate(crash_slots):
            n_pbe = PBES[ki % len(PBES)]
            plan.append((scheme, k, n_pbe, policies[n_pbe]))
            plan.append((scheme, k, n_pbe, None))        # default, mixed
    configs = [PCSConfig(scheme=s, n_pbe=p, n_cores=n_cores,
                         n_tenants=n_tenants,
                         policy=pol).with_crash(fuzz_crash_ns(k))
               for s, k, p, pol in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the mixed {trace x scheme x crash-point x policy} matrix must "
        "be one XLA program")
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, n_pbe, pol) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants, policy=pol)
            assert_cell_matches(
                cells[i][j], oracle, N_ADDRS,
                label=("QOS", seeds[i], scheme.name, k, n_pbe,
                       "default" if pol is None else str(pol.alloc)))


def test_differential_matrix_latency_target_one_compile():
    """Serving-SLO drain tightening (``DrainPolicy.latency_target_ns``)
    vs the oracle twin.  The untimed oracle cannot compute ack
    latencies, so the matrix only uses *extreme* targets where the
    per-persist over/under outcome is timing-independent in the
    prompt-ack fuzz regime: 1 ns (every timed ack is over, so
    drain-down is tight from the very first persist) and 1e12 ns (no
    ack is ever over, so the cell must behave exactly like the default
    policy).  All three policies ride in ONE compiled grid; the
    engine's ``slo_violations`` and histogram mass must match the
    oracle's completion accounting per tenant, the huge-target column
    must be bit-identical to the no-target column, and the
    macro-stepped fast path must agree bit-exactly with the macro-off
    control while the tight override is active."""
    n_tenants, n_cores = 2, 4
    seeds = list(range(4))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in seeds])
    tight = PBPolicy(drain=DrainPolicy(latency_target_ns=1.0))
    never = PBPolicy(drain=DrainPolicy(latency_target_ns=1e12))
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = [(scheme, k, PBES[ki % len(PBES)], pol)
            for scheme in SCHEMES
            for ki, k in enumerate(crash_slots)
            for pol in (tight, never, None)]
    configs = [PCSConfig(scheme=s, n_pbe=p, n_cores=n_cores,
                         n_tenants=n_tenants,
                         policy=pol).with_crash(fuzz_crash_ns(k))
               for s, k, p, pol in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the {trace x scheme x crash-point x latency-target} matrix "
        "must be one XLA program")
    off = simulate_grid(list(traces), configs, max_pbe=max(PBES),
                        bucket=BUCKET, track_addrs=N_ADDRS, macro=False)
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, n_pbe, pol) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, n_pbe,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants, policy=pol)
            label = ("SLO", seeds[i], scheme.name, k, n_pbe,
                     None if pol is None
                     else pol.drain.latency_target_ns)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS, label=label)
            _assert_simresults_identical(cells[i][j], off[i][j], label)
    # a never-reached target must be indistinguishable from no target:
    # plan interleaves (tight, never, None) per (scheme, crash) group
    for i in range(len(seeds)):
        for j in range(0, len(plan), 3):
            _assert_simresults_identical(
                cells[i][j + 1], cells[i][j + 2],
                ("SLO-huge-vs-none", seeds[i], plan[j][0].name,
                 plan[j][1]))


def test_differential_matrix_switch_chains_one_compile():
    """Chained pooling topologies (per-switch PBs): the {trace x scheme
    x depth 1..3 x crash-point} matrix must be ONE XLA program (depth
    and per-hop capacities are traced), with exact engine<->oracle
    agreement on the durable state, the global counts AND the per-hop
    survivor/telemetry rows at every crash point.  Depth-1 cells ride
    in the same mixed-depth grid — the legacy-compat anchor."""
    seeds = list(range(5))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=N_CORES, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   p_persist=0.7)
        for s in seeds])
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    # depth axis: single switch, uniform chain, and a bypass-heavy
    # chain whose deep hops are smaller than hop 1
    chains = [(1, None), (2, (3, 3)), (3, (3, 2, 1))]
    plan = []
    for scheme in SCHEMES:
        for d, hop_pbes in chains:
            for k in crash_slots:
                plan.append((scheme, d, hop_pbes, k))
    configs = [PCSConfig(scheme=s, n_pbe=3, n_switches=d,
                         pbe_per_hop=(None if s == Scheme.NOPB
                                      else hop_pbes)
                         ).with_crash(fuzz_crash_ns(k))
               for s, d, hop_pbes, k in plan]
    n_cells = len(seeds) * len(configs)
    assert n_cells >= 200, n_cells
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=3,
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the mixed {trace x scheme x depth x crash-point} chain matrix "
        "must be one XLA program")
    for i, sched in enumerate(scheds):
        for j, (scheme, d, hop_pbes, k) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, 3, n_switches=d,
                                   pbe_per_hop=hop_pbes)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=("CHAIN", seeds[i], scheme.name,
                                       d, hop_pbes, k))


@pytest.mark.slow
def test_differential_matrix_switch_chains_big():
    """The full-budget chain matrix: more seeds, depth up to 4, mixed
    hop capacities and a multi-tenant chain group — still one compiled
    grid per call (make test-all / tier-1 lane)."""
    seeds = list(range(8))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=N_CORES, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   p_persist=0.75)
        for s in seeds])
    chains = [(1, None), (2, (4, 1)), (3, (4, 2, 2)), (4, (2, 1, 1, 1))]
    crash_slots = (0, 7, 15, 23, 31, 42, N_SLOTS)
    plan = [(s, d, hp, k) for s in SCHEMES for d, hp in chains
            for k in crash_slots]
    configs = [PCSConfig(scheme=s, n_pbe=(4 if hp is None else hp[0]),
                         n_switches=d,
                         pbe_per_hop=(None if s == Scheme.NOPB else hp)
                         ).with_crash(fuzz_crash_ns(k))
               for s, d, hp, k in plan]
    assert len(seeds) * len(configs) >= 500
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=4,
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1
    for i, sched in enumerate(scheds):
        for j, (scheme, d, hp, k) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme,
                                   4 if hp is None else hp[0],
                                   n_switches=d, pbe_per_hop=hp)
            assert_cell_matches(cells[i][j], oracle, N_ADDRS,
                                label=("CHAIN-BIG", seeds[i],
                                       scheme.name, d, hp, k))
    # multi-tenant chain group: per-tenant accounting and per-hop
    # recovery attribution must both hold on a shared chained switch
    n_tenants, n_cores = 2, 4
    t_traces, t_scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in range(3)])
    t_plan = [(s, k) for s in SCHEMES for k in (11, 29, N_SLOTS)]
    t_configs = [PCSConfig(scheme=s, n_pbe=4, n_cores=n_cores,
                           n_tenants=n_tenants,
                           n_switches=2).with_crash(fuzz_crash_ns(k))
                 for s, k in t_plan]
    t_cells = simulate_grid(list(t_traces), t_configs, max_pbe=4,
                            bucket=BUCKET, track_addrs=N_ADDRS)
    for i, (tr, sched) in enumerate(zip(t_traces, t_scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k) in enumerate(t_plan):
            oracle = oracle_replay(sched, k, scheme, 4,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants, n_switches=2)
            assert_cell_matches(t_cells[i][j], oracle, N_ADDRS,
                                label=("CHAIN-T2", i, scheme.name, k))


def test_differential_matrix_fabric_one_compile():
    """Fan-out fabric topologies (leaves + spine) vs the leaf-aware
    oracle: the {trace x scheme x topology x placement x crash-point}
    matrix — plain 2-hop chain, 1-leaf fabric, 2-leaf packed/spread,
    2-leaf with a finite backpressure watermark and a 4-leaf tree, all
    with the same total leaf capacity — must be ONE XLA program, with
    exact agreement on the durable state, the per-tenant rows, the
    per-hop rows AND the per-leaf recovery attribution
    (``SimResult.leaf_recovery``) at every crash point.  Pins two
    identities on top: the 1-leaf fabric column is bit-identical to the
    explicit chain column, and the macro-stepped grid is bit-identical
    to the macro-off control."""
    n_tenants, n_cores = 4, 4
    seeds = list(range(3))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in seeds])
    # all topologies keep sum(leaf_pbe) == 8 and spine_pbe == 4, so the
    # chain control below is the exact 1-leaf/None lowering target
    fabrics = [
        None,                                          # explicit chain
        FabricTopology(1, (8,), 4, (0,) * n_tenants),  # 1-leaf == chain
        FabricTopology(2, (4, 4), 4, leaf_placement(n_tenants, 2,
                                                    "packed")),
        FabricTopology(2, (4, 4), 4, leaf_placement(n_tenants, 2,
                                                    "spread")),
        FabricTopology(2, (4, 4), 4, leaf_placement(n_tenants, 2,
                                                    "packed"),
                       bp_high=2.0),
        FabricTopology(4, (2, 2, 2, 2), 4, leaf_placement(n_tenants, 4,
                                                          "spread")),
    ]
    schemes = [Scheme.PB, Scheme.PB_RF]   # NOPB + fabric raises
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = [(s, k, fab) for s in schemes for k in crash_slots
            for fab in fabrics]
    configs = [
        (PCSConfig(scheme=s, n_pbe=8, n_cores=n_cores,
                   n_tenants=n_tenants, n_switches=2,
                   pbe_per_hop=(8, 4)).with_crash(fuzz_crash_ns(k))
         if fab is None else
         PCSConfig(scheme=s, n_cores=n_cores, n_tenants=n_tenants,
                   fabric=fab).with_crash(fuzz_crash_ns(k)))
        for s, k, fab in plan]
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=8,
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the mixed {trace x scheme x topology x placement x crash-point}"
        " fabric matrix must be one XLA program")
    off = simulate_grid(list(traces), configs, max_pbe=8,
                        bucket=BUCKET, track_addrs=N_ADDRS, macro=False)
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, fab) in enumerate(plan):
            if fab is None:
                oracle = oracle_replay(sched, k, scheme, 8,
                                       core_tenant=core_tenant,
                                       n_tenants=n_tenants,
                                       n_switches=2, pbe_per_hop=(8, 4))
            else:
                oracle = oracle_replay(sched, k, scheme, 8,
                                       core_tenant=core_tenant,
                                       n_tenants=n_tenants, fabric=fab)
            label = ("FAB", seeds[i], scheme.name, k,
                     None if fab is None else
                     (fab.n_leaves, fab.placement, fab.bp_high))
            assert_cell_matches(cells[i][j], oracle, N_ADDRS, label=label)
            _assert_simresults_identical(cells[i][j], off[i][j], label)
            # the engine must attribute recovery per leaf exactly when
            # the topology has >= 2 leaves, and never otherwise
            want_leaf = fab is not None and fab.n_leaves >= 2
            assert (cells[i][j].leaf_recovery is not None) == want_leaf, \
                label
    # plan is fabric-innermost: each group of len(fabrics) shares one
    # (scheme, crash) pair, so chain (index 0) and the 1-leaf fabric
    # (index 1) must be bit-identical cells
    for i in range(len(seeds)):
        for j in range(0, len(plan), len(fabrics)):
            _assert_simresults_identical(
                cells[i][j], cells[i][j + 1],
                ("FAB-1leaf-vs-chain", seeds[i], plan[j][0].name,
                 plan[j][1]))


def test_differential_matrix_epoch_schedules_one_compile():
    """Epoched config schedules vs the epoch-aware oracle: knobs that
    are piecewise-constant time schedules (``params.Schedule``) — a
    mid-run tenant-quota step, a mid-run drain-threshold tighten and a
    mid-run tenant->leaf placement flip — mixed with static controls in
    ONE compiled grid, with exact engine<->oracle agreement on the
    durable state, the per-tenant rows AND the per-leaf recovery
    attribution at crash points *before, at-large and after* the epoch
    boundary.  The boundary sits at a half-slot instant
    (``fuzz_crash_ns`` convention), so the oracle's slot-epoch equals
    the engine's issue-time epoch by construction.  The macro-stepped
    grid must stay bit-identical to the macro-off control (windows
    straddling the boundary abort under the ``epoch_boundary`` reason
    instead of committing mixed-epoch replays)."""
    n_tenants, n_cores = 4, 4
    seeds = list(range(3))
    traces, scheds = zip(*[
        fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS, n_addrs=N_ADDRS,
                   n_tenants=n_tenants, p_persist=0.7)
        for s in seeds])
    bound = fuzz_crash_ns(25)                 # epoch 1 from slot 26 on
    quota_sched = PBPolicy(alloc=AllocPolicy(
        tenant_quota=Schedule((bound,), ((2, 2, 2, 2), (5, 1, 1, 1)))))
    thr_sched = PBPolicy(drain=DrainPolicy(
        threshold=Schedule((bound,), (0.75, 0.375)), preset=0.25))
    pol_variants = [quota_sched, thr_sched, None]      # None = static
    place0 = leaf_placement(n_tenants, 2, "packed")
    place1 = tuple(1 - p for p in place0)              # hot-leaf flip
    fab_sched = FabricTopology(2, (4, 4), 4,
                               Schedule((bound,), (place0, place1)))
    # crash points on both sides of the boundary, plus the boundary's
    # own neighborhood (23 < 25.5 < 36) and the full run
    crash_slots = (0, 11, 23, 36, N_SLOTS)
    plan = []
    for k in crash_slots:
        for scheme in SCHEMES:
            for pol in pol_variants:
                plan.append((scheme, k, pol, None))
        for scheme in (Scheme.PB, Scheme.PB_RF):       # NOPB+fabric raises
            plan.append((scheme, k, None, fab_sched))
    configs = [
        (PCSConfig(scheme=s, n_cores=n_cores, n_tenants=n_tenants,
                   fabric=fab).with_crash(fuzz_crash_ns(k))
         if fab is not None else
         PCSConfig(scheme=s, n_pbe=8, n_cores=n_cores,
                   n_tenants=n_tenants,
                   policy=pol).with_crash(fuzz_crash_ns(k)))
        for s, k, pol, fab in plan]
    assert any(c.n_epochs == 2 for c in configs)
    c0 = compile_count()
    cells = simulate_grid(list(traces), configs, max_pbe=8,
                          bucket=BUCKET, track_addrs=N_ADDRS)
    assert compile_count() - c0 == 1, (
        "the mixed {static x scheduled} epoch matrix must be one XLA "
        "program")
    off = simulate_grid(list(traces), configs, max_pbe=8,
                        bucket=BUCKET, track_addrs=N_ADDRS, macro=False)
    for i, (tr, sched) in enumerate(zip(traces, scheds)):
        core_tenant = tenant_ids(tr.lengths, n_tenants)
        for j, (scheme, k, pol, fab) in enumerate(plan):
            oracle = oracle_replay(sched, k, scheme, 8,
                                   core_tenant=core_tenant,
                                   n_tenants=n_tenants, policy=pol,
                                   fabric=fab)
            label = ("EPOCH", seeds[i], scheme.name, k,
                     "placement" if fab is not None else
                     "static" if pol is None else
                     "quota" if pol is quota_sched else "threshold")
            assert_cell_matches(cells[i][j], oracle, N_ADDRS, label=label)
            _assert_simresults_identical(cells[i][j], off[i][j], label)


def test_fabric_validation_rejects_malformed():
    """Construction-time validation (no silent mis-lowering): malformed
    fabric descriptors, fabric/chain conflicts and grids stacked with
    too-small static bounds must all raise — never truncate."""
    from repro.core.engine.state import scalars_from_config

    with pytest.raises(ValueError, match="leaf_pbe"):
        FabricTopology(n_leaves=2, leaf_pbe=(4,), spine_pbe=4,
                       placement=(0, 1))
    with pytest.raises(ValueError, match="placement"):
        FabricTopology(n_leaves=2, leaf_pbe=(4, 4), spine_pbe=4,
                       placement=(0, 2))
    with pytest.raises(ValueError, match="bp_high"):
        FabricTopology(n_leaves=1, leaf_pbe=(8,), spine_pbe=4,
                       placement=(0,), bp_high=2.0)
    fab2 = FabricTopology(2, (4, 4), 4, (0, 1))
    with pytest.raises(ValueError, match="NOPB"):
        PCSConfig(scheme=Scheme.NOPB, n_cores=2, n_tenants=2, fabric=fab2)
    with pytest.raises(ValueError, match="one leaf id per tenant"):
        PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=3,
                  fabric=fab2)
    with pytest.raises(ValueError, match="fabric owns it"):
        PCSConfig(scheme=Scheme.PB_RF, n_cores=2, n_tenants=2,
                  fabric=fab2, n_switches=2, pbe_per_hop=(5, 4))
    with pytest.raises(ValueError, match="two-level tree"):
        PCSConfig(scheme=Scheme.PB_RF, n_cores=2, n_tenants=2,
                  fabric=fab2, n_switches=3)
    # the derived lowering is visible: 2 hops, (sum(leaf_pbe), spine)
    cfg = PCSConfig(scheme=Scheme.PB_RF, n_cores=2, n_tenants=2,
                    fabric=fab2)
    assert (cfg.n_switches, cfg.pbe_per_hop, cfg.n_pbe) == (2, (8, 4), 8)
    # static grid bounds reject instead of truncating (a dropped deep
    # row / leaf would lower a different topology with the right shape)
    with pytest.raises(ValueError, match="leaf bound"):
        scalars_from_config(cfg, n_tenants_max=2, n_deep_max=1,
                            n_leaves_max=1)
    deep = PCSConfig(scheme=Scheme.PB_RF, n_switches=3,
                     pbe_per_hop=(2, 2, 2))
    with pytest.raises(ValueError, match="deep-row bound"):
        scalars_from_config(deep, n_tenants_max=1, n_deep_max=1,
                            n_leaves_max=1)


def _assert_simresults_identical(a, b, label):
    """Exact equality over every SimResult field — arrays bitwise equal
    (per-tenant and per-hop rows included), scalars equal with NaN==NaN
    (empty cells have NaN mean latencies on both sides)."""
    for f in a.__dataclass_fields__:
        x, y = getattr(a, f), getattr(b, f)
        if isinstance(x, np.ndarray):
            assert y is not None and np.array_equal(x, y), (label, f)
        else:
            both_nan = (isinstance(x, float) and isinstance(y, float)
                        and np.isnan(x) and np.isnan(y))
            assert x == y or both_nan, (label, f, x, y)


def test_differential_macro_column_bit_exact():
    """The macro-enabled engine column vs the macro-disabled control
    over the same fuzzed cells: exact SimResult equality.  Covers the
    single-tenant matrix (with a depth-2 chain group: the deep guard
    must abort cleanly) and a T=2 multi-tenant group, at crash points
    that land mid-window as well as past the stream end."""
    seeds = list(range(4))
    traces = [fuzz_trace(s, n_cores=N_CORES, n_slots=N_SLOTS,
                         n_addrs=N_ADDRS)[0] for s in seeds]
    plan = [(scheme, k, PBES[ki % len(PBES)], d)
            for scheme in SCHEMES
            for ki, k in enumerate((0, 13, 29, N_SLOTS))
            for d in (1, 2)]
    configs = [PCSConfig(scheme=s, n_pbe=p,
                         n_switches=d).with_crash(fuzz_crash_ns(k))
               for s, k, p, d in plan]
    on = simulate_grid(traces, configs, max_pbe=max(PBES), bucket=BUCKET,
                       track_addrs=N_ADDRS)
    off = simulate_grid(traces, configs, max_pbe=max(PBES), bucket=BUCKET,
                        track_addrs=N_ADDRS, macro=False)
    for i, s in enumerate(seeds):
        for j, (scheme, k, p, d) in enumerate(plan):
            _assert_simresults_identical(
                on[i][j], off[i][j], (s, scheme.name, k, p, d))
            # derived percentile outputs ride on the (bitwise-equal)
            # histogram rows, but pin them too: the user-facing numbers
            # must not depend on whether macro-stepping was on
            for q in (0.50, 0.95, 0.99):
                x = on[i][j].persist_lat_pct(q)
                y = off[i][j].persist_lat_pct(q)
                assert x == y or (np.isnan(x) and np.isnan(y)), (
                    s, scheme.name, k, p, d, q, x, y)

    n_tenants, n_cores = 2, 4
    t_traces = [fuzz_trace(s, n_cores=n_cores, n_slots=N_SLOTS,
                           n_addrs=N_ADDRS, n_tenants=n_tenants)[0]
                for s in range(2)]
    t_configs = [PCSConfig(scheme=s, n_pbe=4, n_cores=n_cores,
                           n_tenants=n_tenants).with_crash(fuzz_crash_ns(k))
                 for s in SCHEMES for k in (11, 29, N_SLOTS)]
    t_on = simulate_grid(t_traces, t_configs, max_pbe=4, bucket=BUCKET,
                         track_addrs=N_ADDRS)
    t_off = simulate_grid(t_traces, t_configs, max_pbe=4, bucket=BUCKET,
                          track_addrs=N_ADDRS, macro=False)
    for i in range(len(t_traces)):
        for j in range(len(t_configs)):
            _assert_simresults_identical(t_on[i][j], t_off[i][j],
                                         ("T2", i, j))


def _one_cell(seed, scheme, crash_slot, n_pbe, p_persist=0.55):
    trace, sched = fuzz_trace(seed, n_cores=N_CORES, n_slots=N_SLOTS,
                              n_addrs=N_ADDRS, p_persist=p_persist)
    res = simulate(trace,
                   PCSConfig(scheme=scheme, n_pbe=n_pbe).with_crash(
                       fuzz_crash_ns(crash_slot)),
                   max_pbe=max(PBES), bucket=BUCKET, track_addrs=N_ADDRS)
    oracle = oracle_replay(sched, crash_slot, scheme, n_pbe)
    assert_cell_matches(res, oracle, N_ADDRS,
                        label=(seed, scheme.name, crash_slot, n_pbe))


if HAVE_HYPOTHESIS:

    @settings(max_examples=N_EXAMPLES, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        scheme=st.sampled_from(SCHEMES),
        crash_slot=st.integers(0, N_SLOTS),
        n_pbe=st.sampled_from(PBES),
        p_persist=st.floats(0.1, 0.9),
    )
    def test_differential_fuzz(seed, scheme, crash_slot, n_pbe, p_persist):
        _one_cell(seed, scheme, crash_slot, n_pbe, p_persist)

else:

    @pytest.mark.parametrize("case", range(N_EXAMPLES))
    def test_differential_fuzz(case):
        import random
        rng = random.Random(0xC0FFEE + case)
        _one_cell(seed=rng.randrange(2**31),
                  scheme=rng.choice(SCHEMES),
                  crash_slot=rng.randrange(N_SLOTS + 1),
                  n_pbe=rng.choice(PBES),
                  p_persist=rng.uniform(0.1, 0.9))
