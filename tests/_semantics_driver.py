"""Shared schedule driver for the PB state-machine tests.

Used by the deterministic tests (tests/test_semantics.py) and, when
``hypothesis`` is installed, the property tests
(tests/test_semantics_props.py).
"""
from repro.core import PCSConfig
from repro.core.semantics import EventKind, PersistentBuffer


def run_schedule(scheme, n_pbe, ops, ack_order):
    """Drive the buffer with a schedule; return (pb, acked, reads).

    PM write-acks may be reordered freely *across* addresses, but stay
    FIFO *per address*: same-address drains travel the same
    switch->PM->switch path (the protocol's write-order argument rests
    on this), so a newer version's ack can never overtake an older one.
    """
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=n_pbe))
    acked = {}
    pending = []
    reads = []
    version_of_payload = {}
    ai = 0
    for op, addr in ops:
        if op == "persist":
            payload = f"{addr}@{len(version_of_payload)}"
            for e in pb.persist(addr, payload):
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    acked[e.addr] = max(acked.get(e.addr, -1), e.version)
                    version_of_payload[(e.addr, e.version)] = payload
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
        elif op == "ack" and pending:
            i = ack_order[ai % len(ack_order)] % len(pending)
            ai += 1
            a, _ = pending[i]
            # per-address FIFO: deliver the oldest in-flight version
            a, v = min((p for p in pending if p[0] == a),
                       key=lambda p: p[1])
            pending.remove((a, v))
            for e in pb.pm_ack(a, v):
                if e.kind == EventKind.DRAIN_SENT:
                    pending.append((e.addr, e.version))
                if e.kind in (EventKind.PERSIST_ACK, EventKind.COALESCED):
                    acked[e.addr] = max(acked.get(e.addr, -1), e.version)
        else:
            data, ev = pb.read(addr)
            reads.append((addr, data, ev))
        pb.check_invariants()
    return pb, acked, reads
