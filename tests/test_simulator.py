"""Timed PCS simulator: latency composition, scheme behaviour, sweeps."""
import numpy as np
import pytest

from repro.core import (LatencyProfile, Op, PCSConfig, Scheme, Trace,
                        make_trace, simulate, simulate_sweep)


def tiny_trace(n_persists=64, n_reads=64, gap=2000.0, n_cores=1, addr_stride=1):
    ops, addrs, gaps = [], [], []
    for i in range(n_persists):
        ops.append(int(Op.PERSIST))
        addrs.append(i * addr_stride)
        gaps.append(gap)
    for i in range(n_reads):
        ops.append(int(Op.PM_READ))
        addrs.append((1 << 20) + i)
        gaps.append(gap)
    C = n_cores
    return Trace(ops=np.tile(np.array(ops, np.int32), (C, 1)),
                 addrs=np.tile(np.array(addrs, np.int32), (C, 1)),
                 gaps=np.tile(np.array(gaps, np.float32), (C, 1)),
                 lengths=np.full((C,), len(ops), np.int32), name="tiny")


def test_nopb_latency_composition():
    """Uncongested persist = 2x one-way + NVM write; read = 2x ow + read."""
    lat = LatencyProfile()
    cfg = PCSConfig(scheme=Scheme.NOPB, n_switches=1, latency=lat)
    res = simulate(tiny_trace(), cfg)
    ow = lat.oneway_cpu_pm(1)
    assert abs(res.persist_lat_ns - (2 * ow + lat.nvm_write_ns)) < 1.0
    assert abs(res.read_lat_ns - (2 * ow + lat.nvm_read_ns)) < 1.0


def test_pb_ack_at_switch():
    """Uncongested PB persist completes at the first switch."""
    lat = LatencyProfile()
    cfg = PCSConfig(scheme=Scheme.PB, n_switches=1, latency=lat)
    res = simulate(tiny_trace(), cfg)
    expect = (2 * lat.oneway_cpu_sw1() + lat.pbc_proc_ns
              + lat.pb_tag_ns_for(16) + lat.pb_data_ns_for(16))
    assert abs(res.persist_lat_ns - expect) < 1.0
    assert res.persist_lat_ns < 0.6 * (2 * lat.oneway_cpu_pm(1)
                                       + lat.nvm_write_ns)


def test_persist_latency_grows_with_switch_depth():
    """Fig 1: NoPB persist latency grows with chain depth; PB stays flat."""
    lats_nopb, lats_pb = [], []
    for n_sw in (1, 2, 3):
        tr = tiny_trace()
        lats_nopb.append(simulate(
            tr, PCSConfig(scheme=Scheme.NOPB, n_switches=n_sw)).persist_lat_ns)
        lats_pb.append(simulate(
            tr, PCSConfig(scheme=Scheme.PB, n_switches=n_sw)).persist_lat_ns)
    assert lats_nopb[0] < lats_nopb[1] < lats_nopb[2]
    assert lats_pb[2] - lats_pb[0] < 0.2 * (lats_nopb[2] - lats_nopb[0])


def test_rf_coalesces_hot_writes():
    tr = tiny_trace(n_persists=64, addr_stride=0)   # same line repeatedly
    res = simulate(tr, PCSConfig(scheme=Scheme.PB_RF))
    assert res.coalesces > 40
    assert res.pm_writes < 20


def test_pb_never_coalesces():
    tr = tiny_trace(n_persists=64, addr_stride=0)
    res = simulate(tr, PCSConfig(scheme=Scheme.PB))
    assert res.coalesces == 0
    assert res.pm_writes == 64


def test_rf_read_hits_recent_persists():
    ops = []
    for i in range(32):
        ops.append((int(Op.PERSIST), i % 4))
        ops.append((int(Op.PM_READ), i % 4))
    tr = Trace(ops=np.array([[o for o, _ in ops]], np.int32),
               addrs=np.array([[a for _, a in ops]], np.int32),
               gaps=np.full((1, len(ops)), 500.0, np.float32),
               lengths=np.array([len(ops)], np.int32), name="hot")
    res = simulate(tr, PCSConfig(scheme=Scheme.PB_RF))
    assert res.read_hit_rate > 0.9


@pytest.mark.slow
def test_sweep_matches_individual():
    tr = make_trace("radiosity", persist_budget=3000)
    cfgs = [PCSConfig(scheme=Scheme.PB, n_pbe=n) for n in (8, 16, 32)]
    sweep = simulate_sweep(tr, cfgs)
    for cfg, r in zip(cfgs, sweep):
        ri = simulate(tr, cfg, max_pbe=32)
        assert abs(r.runtime_ns - ri.runtime_ns) / ri.runtime_ns < 1e-9


@pytest.mark.parametrize("name", ["radiosity", "cholesky", "fft"])
@pytest.mark.slow
def test_workload_scheme_ordering(name):
    """Qualitative paper signatures on reduced-budget traces."""
    tr = make_trace(name, persist_budget=4000)
    res = {s: simulate(tr, PCSConfig(scheme=s))
           for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)}
    nopb, pb, rf = (res[s] for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF))
    # persist latency reduced by PB for every workload (Fig 6a)
    assert pb.persist_lat_ns < 0.8 * nopb.persist_lat_ns
    if name == "radiosity":
        assert rf.read_hit_rate > 0.3                  # Fig 7a
        assert rf.coalesce_rate > 0.3                  # Fig 7b
        assert nopb.runtime_ns / pb.runtime_ns > 1.05  # Fig 5
    if name == "cholesky":
        assert rf.read_hit_rate < 0.1
        assert rf.coalesce_rate < 0.05
        assert abs(nopb.runtime_ns / pb.runtime_ns - 1.0) < 0.15
    if name == "fft":
        assert 0.05 < rf.read_hit_rate < 0.45
        assert rf.coalesce_rate < 0.15


def test_trace_generators_respect_budget():
    for name in ("radiosity", "fft", "cholesky"):
        tr = make_trace(name, persist_budget=2000)
        assert tr.counts()["persist"] <= 2000
