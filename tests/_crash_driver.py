"""Differential crash-point conformance driver: engine <-> oracle.

The fuzzer (``core.traces.fuzz_trace``) emits slot-spaced multi-core
persist/read/barrier interleavings whose engine execution order provably
equals the slot order, with every drain acked inside its slot (the
prompt-ack regime).  Crashing the timed engine at ``fuzz_crash_ns(k)``
and the untimed oracle after replaying slots ``<= k`` is therefore the
*same logical point*, and the paper's correctness argument requires the
two layers to agree exactly on the durable state that recovery
(Section V-D4) reconstructs:

  * no acked version is lost — every persist acked before the crash is
    durable after recovery;
  * no unacked version is resurrected — recovery preserves exactly the
    newest pre-crash version per address, never a fabricated one;
  * read forwarding never returns a value recovery would discard.

``oracle_replay`` returns the oracle's view; ``assert_cell_matches``
pins the engine's ``SimResult`` (run with ``track_addrs`` and a
``crash_at_ns`` config) against it.
"""
import collections

from repro.core import Op, PCSConfig, Scheme
from repro.core.semantics import EventKind, PersistentBuffer
from repro.core.traces import FUZZ_SLOT_GAP_NS


def _counts_from(stats, scheme, victim_stalls):
    return dict(
        persists=stats["persists"],
        coalesces=stats["coalesces"],
        read_hits=stats["read_hits"],
        pm_reads=stats["read_hits"] + stats["read_misses"],
        # writes that reached the PM device: under a switch chain the
        # hop-1 drain count is NOT the PM write count (deep hops retain
        # and coalesce), so the oracle tracks device arrivals explicitly
        pm_writes=stats["pm_writes"],
        victim_drains=victim_stalls,
        slo_violations=stats.get("slo_over", 0),
    )


def oracle_replay(schedule, crash_slot, scheme, n_pbe, core_tenant=None,
                  n_tenants=1, policy=None, n_switches=1,
                  pbe_per_hop=None, fabric=None):
    """Replay schedule slots ``<= crash_slot``, then crash + recover.

    Acks are delivered promptly (all in-flight drains complete between
    slots, FIFO in emission order), mirroring the fuzzed traces' timing.
    Returns a dict with the durable per-address versions, the pre-crash
    event counts the engine must reproduce, and the read log.

    ``core_tenant`` (from ``core.traces.tenant_ids``) maps each core to
    the tenant the shared switch bills its requests to; the returned
    ``tenant_counts`` row per tenant must match the engine's per-tenant
    stats rows exactly.  ``policy`` (a ``PBPolicy``) drives the oracle's
    quota / victim / drain-scope decisions — the engine cell must be run
    with the *same* policy on its config.  ``n_switches`` /
    ``pbe_per_hop`` select a chained pooling topology: the returned
    ``hop_surviving`` / ``hop_counts`` rows must match the engine's
    per-hop recovery attribution and telemetry exactly.  ``fabric`` (a
    ``FabricTopology``) selects a fan-out tree instead: it forces the
    derived 2-hop shape (leaves + spine), and the returned
    ``leaf_surviving`` row must match the engine's per-leaf recovery
    attribution (``SimResult.leaf_recovery``).
    """
    pb = PersistentBuffer(PCSConfig(
        scheme=scheme, n_pbe=n_pbe, n_tenants=n_tenants, policy=policy,
        n_switches=n_switches, fabric=fabric,
        pbe_per_hop=(None if scheme == Scheme.NOPB or fabric is not None
                     else pbe_per_hop)))
    # SLO hint for the untimed oracle: the differential only exercises
    # *extreme* latency targets (<= 1 ns: every timed ack is over; huge:
    # none is), so the per-persist over/under outcome is decidable
    # without timing — the driver computes it once, up front
    lat_target = policy.drain.latency_target_ns if policy is not None \
        else None
    lat_over = lat_target is not None and lat_target <= 1.0
    aver = collections.defaultdict(int)   # per-address issued versions
    # under a multi-leaf fabric the hop-1 PB is leaf-partitioned: a read
    # from a *different* leaf than the newest persist cannot be forwarded
    # the leaf-private copy — it legitimately serves PM's durable version.
    # Track the newest persist's leaf so the read contract can tell the
    # two regimes apart (same-leaf reads keep the strict newest rule).
    multi_leaf = fabric is not None and fabric.n_leaves >= 2
    last_leaf = {}                        # addr -> leaf of newest persist
    pending = []
    victim_stalls = collections.defaultdict(int)
    reads = []
    for slot, core, op, addr in schedule:
        if slot > crash_slot:
            break
        # epoched schedules: the fuzzed slots issue at ~slot * gap with
        # sub-half-slot drift, and the tests place epoch boundaries at
        # half-slot instants (fuzz_crash_ns convention), so the slot's
        # nominal issue time selects exactly the engine's issue-time
        # epoch; schedule-free configs never leave epoch 0
        ep = pb.epoch_at(slot * FUZZ_SLOT_GAP_NS)
        if ep != pb.epoch:
            pb.set_epoch(ep)
        if op == int(Op.BARRIER):
            continue
        tenant = int(core_tenant[core]) if core_tenant is not None else 0
        if op == int(Op.PERSIST):
            aver[addr] += 1
            if multi_leaf:
                # placement resolved at the *current epoch* — entries
                # never migrate, so the newest copy lives on the leaf
                # the persist was issued to
                last_leaf[addr] = pb._placement[tenant]
            events = pb.persist(addr, (addr, aver[addr]), tenant=tenant,
                                lat_over=lat_over)
            victim_stalls[tenant] += sum(
                1 for e in events if e.kind == EventKind.STALLED)
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
        else:
            data, _ev = pb.read(addr, tenant=tenant)
            same_leaf = (not multi_leaf or addr not in last_leaf
                         or last_leaf[addr] == pb._placement[tenant])
            reads.append((addr, data, aver[addr], same_leaf))
        while pending:
            a, v = pending.pop(0)
            events = pb.pm_ack(a, v)
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
        pb.check_invariants()
    counts = _counts_from(pb.stats, scheme, sum(victim_stalls.values()))
    # NoPB applies exactly one PM write per persist; for the global row
    # keep the device's own applied-writes counter as the source of truth
    if scheme == Scheme.NOPB:
        counts["pm_writes"] = pb.pm.writes_applied
    zero = {k: 0 for k in pb.stats}
    tenant_counts = [
        _counts_from(pb.tenant_stats.get(t, zero), scheme,
                     victim_stalls[t])
        for t in range(n_tenants)]
    snapshot = {a: rec[0] for a, rec in pb.snapshot_durable().items()}
    # surviving (non-Empty) PBEs at the crash instant, per owning tenant
    # and per hop of the switch chain: the engine's recovery_entries /
    # tenant_recovery / hop_recovery must match exactly
    tenant_surviving = [0] * n_tenants
    for hop in [pb.entries, *pb.hops]:
        for e in hop:
            if e.state.name != "EMPTY":
                tenant_surviving[e.tenant] += 1
    hop_surviving = pb.hop_surviving()
    leaf_surviving = pb.leaf_surviving()
    hop_counts = [dict(hc) for hc in pb.hop_counts]
    pb.crash()
    pb.recover()
    durable = {}
    for addr, (gver, payload) in pb.pm.store.items():
        assert payload[0] == addr
        durable[addr] = payload[1]          # per-address version number
    # the non-mutating snapshot must predict recovery exactly
    assert {a: rec[0] for a, rec in pb.pm.store.items()} == snapshot, \
        "snapshot_durable disagrees with crash+recover"
    return dict(durable=durable, counts=counts, reads=reads,
                issued=dict(aver), tenant_counts=tenant_counts,
                tenant_surviving=tenant_surviving,
                hop_surviving=hop_surviving, hop_counts=hop_counts,
                leaf_surviving=leaf_surviving)


def assert_cell_matches(res, oracle, n_addrs, label=""):
    """The engine's post-recovery durable state must equal the oracle's."""
    durable = oracle["durable"]
    issued = oracle["issued"]
    got = {a: int(res.durable_ver[a]) for a in range(n_addrs)}
    want = {a: durable.get(a, 0) for a in range(n_addrs)}
    assert got == want, (label, "durable state diverged", got, want)

    counts = dict(persists=res.persists, coalesces=res.coalesces,
                  read_hits=res.read_hits, pm_reads=res.pm_reads,
                  pm_writes=res.pm_writes, victim_drains=res.victim_drains,
                  slo_violations=res.slo_violations)
    assert counts == oracle["counts"], (label, counts, oracle["counts"])
    # the latency histogram is persist-complete accounting: its mass
    # must equal the persist count the oracle agreed on (bit-exact twin
    # of S_PERSIST_CNT, accumulated at the same three engine sites)
    if res.lat_hist is not None:
        assert int(res.lat_hist.sum()) == res.persists, (
            label, "lat_hist mass", int(res.lat_hist.sum()), res.persists)

    # the Section V-D4 recovery pass re-drains exactly the oracle's
    # surviving (non-Empty) entries — the union across every hop
    assert res.recovery_entries == sum(oracle["tenant_surviving"]), (
        label, "recovery entries", res.recovery_entries,
        oracle["tenant_surviving"])

    # per-hop durable-state agreement over the switch chain: survivors
    # and the chain telemetry (commits / coalesces / bypasses / read
    # hits at every switch) must match row by row
    if res.hop_stats is not None:
        hops = res.hop_results()
        assert len(hops) == len(oracle["hop_surviving"]), (
            label, "hop count", len(hops), oracle["hop_surviving"])
        got_hs = [h["recovered"] for h in hops]
        assert got_hs == oracle["hop_surviving"], (
            label, "per-hop survivors", got_hs, oracle["hop_surviving"])
        for h, (got_h, want_h) in enumerate(
                zip(hops, oracle["hop_counts"])):
            got_row = {k: got_h[k] for k in
                       ("commits", "coalesces", "bypasses", "read_hits")}
            assert got_row == want_h, (label, "hop", h + 1, got_row,
                                       want_h)

    # per-leaf recovery attribution over a fan-out fabric: the engine's
    # leaf_recovery vector (non-None iff >= 2 leaves) must equal the
    # oracle's per-leaf survivor counts, which partition hop 1's total
    if res.leaf_recovery is not None:
        got_ls = [int(x) for x in res.leaf_recovery]
        assert got_ls == oracle["leaf_surviving"], (
            label, "per-leaf survivors", got_ls, oracle["leaf_surviving"])
        assert sum(got_ls) == oracle["hop_surviving"][0], (
            label, "leaf/hop partition", got_ls, oracle["hop_surviving"])
    else:
        assert len(oracle["leaf_surviving"]) <= 1, (
            label, "engine dropped leaf attribution",
            oracle["leaf_surviving"])

    # per-tenant accounting over the shared switch must agree row by row
    if res.n_tenants > 1:
        t_rows = res.tenant_results()
        assert len(t_rows) == len(oracle["tenant_counts"]), label
        for t, (tr, want_t) in enumerate(
                zip(t_rows, oracle["tenant_counts"])):
            got_t = dict(persists=tr.persists, coalesces=tr.coalesces,
                         read_hits=tr.read_hits, pm_reads=tr.pm_reads,
                         pm_writes=tr.pm_writes,
                         victim_drains=tr.victim_drains,
                         slo_violations=tr.slo_violations)
            assert got_t == want_t, (label, "tenant", t, got_t, want_t)
        # per-tenant recovery attribution (surviving-entry owners)
        got_surv = [tr.recovery_entries for tr in t_rows]
        assert got_surv == oracle["tenant_surviving"], (
            label, "tenant recovery", got_surv,
            oracle["tenant_surviving"])

    # prompt-ack regime: every executed persist was acked before the
    # crash, and (the paper's claim) every acked persist is durable
    assert res.acked_persists == res.persists, (label, "unacked persists")
    assert res.durable_persists == res.acked_persists, (
        label, "acked version lost")
    # no resurrection: durability never exceeds what was issued
    for a in range(n_addrs):
        assert got[a] <= issued.get(a, 0), (label, "resurrected", a)

    # read forwarding: every value served was the newest at read time
    # and is one recovery preserves (never a discarded version).  Under
    # a multi-leaf fabric a cross-leaf read (the newest persist landed
    # on another leaf's private PB window) legitimately misses the
    # reader's leaf and serves a durable-or-older version instead — but
    # it must never invent a version (> issued) and never serve one
    # recovery discards.
    for addr, data, newest, same_leaf in oracle["reads"]:
        if newest == 0:
            assert data is None, (label, "read invented data", addr)
            continue
        if same_leaf:
            assert data is not None and data == (addr, newest), (
                label, "stale read", addr, data, newest)
        elif data is not None:
            assert data[0] == addr and 1 <= data[1] <= newest, (
                label, "cross-leaf read invented a version", addr, data,
                newest)
        if data is not None:
            assert durable.get(addr, 0) >= data[1], (
                label, "forwarded value discarded by recovery", addr)
