"""Differential crash-point conformance driver: engine <-> oracle.

The fuzzer (``core.traces.fuzz_trace``) emits slot-spaced multi-core
persist/read/barrier interleavings whose engine execution order provably
equals the slot order, with every drain acked inside its slot (the
prompt-ack regime).  Crashing the timed engine at ``fuzz_crash_ns(k)``
and the untimed oracle after replaying slots ``<= k`` is therefore the
*same logical point*, and the paper's correctness argument requires the
two layers to agree exactly on the durable state that recovery
(Section V-D4) reconstructs:

  * no acked version is lost — every persist acked before the crash is
    durable after recovery;
  * no unacked version is resurrected — recovery preserves exactly the
    newest pre-crash version per address, never a fabricated one;
  * read forwarding never returns a value recovery would discard.

``oracle_replay`` returns the oracle's view; ``assert_cell_matches``
pins the engine's ``SimResult`` (run with ``track_addrs`` and a
``crash_at_ns`` config) against it.
"""
import collections

from repro.core import Op, PCSConfig, Scheme
from repro.core.semantics import EventKind, PersistentBuffer


def oracle_replay(schedule, crash_slot, scheme, n_pbe):
    """Replay schedule slots ``<= crash_slot``, then crash + recover.

    Acks are delivered promptly (all in-flight drains complete between
    slots, FIFO in emission order), mirroring the fuzzed traces' timing.
    Returns a dict with the durable per-address versions, the pre-crash
    event counts the engine must reproduce, and the read log.
    """
    pb = PersistentBuffer(PCSConfig(scheme=scheme, n_pbe=n_pbe))
    aver = collections.defaultdict(int)   # per-address issued versions
    pending = []
    victim_stalls = 0
    reads = []
    for slot, _core, op, addr in schedule:
        if slot > crash_slot:
            break
        if op == int(Op.BARRIER):
            continue
        if op == int(Op.PERSIST):
            aver[addr] += 1
            events = pb.persist(addr, (addr, aver[addr]))
            victim_stalls += sum(
                1 for e in events if e.kind == EventKind.STALLED)
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
        else:
            data, _ev = pb.read(addr)
            reads.append((addr, data, aver[addr]))
        while pending:
            a, v = pending.pop(0)
            events = pb.pm_ack(a, v)
            pending += [(e.addr, e.version) for e in events
                        if e.kind == EventKind.DRAIN_SENT]
        pb.check_invariants()
    counts = dict(
        persists=pb.stats["persists"],
        coalesces=pb.stats["coalesces"],
        read_hits=pb.stats["read_hits"],
        pm_reads=pb.stats["read_hits"] + pb.stats["read_misses"],
        pm_writes=(pb.pm.writes_applied if scheme == Scheme.NOPB
                   else pb.stats["drains"]),
        victim_drains=victim_stalls,
    )
    snapshot = {a: rec[0] for a, rec in pb.snapshot_durable().items()}
    pb.crash()
    pb.recover()
    durable = {}
    for addr, (gver, payload) in pb.pm.store.items():
        assert payload[0] == addr
        durable[addr] = payload[1]          # per-address version number
    # the non-mutating snapshot must predict recovery exactly
    assert {a: rec[0] for a, rec in pb.pm.store.items()} == snapshot, \
        "snapshot_durable disagrees with crash+recover"
    return dict(durable=durable, counts=counts, reads=reads,
                issued=dict(aver))


def assert_cell_matches(res, oracle, n_addrs, label=""):
    """The engine's post-recovery durable state must equal the oracle's."""
    durable = oracle["durable"]
    issued = oracle["issued"]
    got = {a: int(res.durable_ver[a]) for a in range(n_addrs)}
    want = {a: durable.get(a, 0) for a in range(n_addrs)}
    assert got == want, (label, "durable state diverged", got, want)

    counts = dict(persists=res.persists, coalesces=res.coalesces,
                  read_hits=res.read_hits, pm_reads=res.pm_reads,
                  pm_writes=res.pm_writes, victim_drains=res.victim_drains)
    assert counts == oracle["counts"], (label, counts, oracle["counts"])

    # prompt-ack regime: every executed persist was acked before the
    # crash, and (the paper's claim) every acked persist is durable
    assert res.acked_persists == res.persists, (label, "unacked persists")
    assert res.durable_persists == res.acked_persists, (
        label, "acked version lost")
    # no resurrection: durability never exceeds what was issued
    for a in range(n_addrs):
        assert got[a] <= issued.get(a, 0), (label, "resurrected", a)

    # read forwarding: every value served was the newest at read time
    # and is one recovery preserves (never a discarded version)
    for addr, data, newest in oracle["reads"]:
        if newest == 0:
            assert data is None, (label, "read invented data", addr)
            continue
        assert data is not None and data == (addr, newest), (
            label, "stale read", addr, data, newest)
        assert durable.get(addr, 0) >= data[1], (
            label, "forwarded value discarded by recovery", addr)
