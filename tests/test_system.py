"""End-to-end system tests: train -> crash -> recover -> resume, with the
PCS persistence tier in each scheme."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.steps import make_train_step
from repro.launch.train import make_manager, restore_state, save_state
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)


class Args:
    def __init__(self, ckpt_dir, scheme="pb_rf"):
        self.scheme = scheme
        self.buffer_mb = 64
        self.ckpt_dir = ckpt_dir
        self.store_delay_ms = 1.0


@pytest.mark.slow
@pytest.mark.parametrize("scheme", ["nopb", "pb", "pb_rf"])
def test_train_crash_resume(tmp_path, scheme):
    cfg = get_config("smollm-135m", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=20)
    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(opt_cfg, params)
    data = SyntheticLMDataset(cfg.vocab, 16, 2)
    step = make_train_step(cfg, opt_cfg)

    mgr = make_manager(Args(str(tmp_path), scheme))
    losses = []
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 3 == 0:
            save_state(mgr, i + 1, params, opt_state, data.state())
    # crash the manager (drainer killed, volatile routing lost), recover
    mgr.crash()
    mgr.recover()

    # a NEW manager over the same durable store must restore step 6 state
    mgr2 = make_manager(Args(str(tmp_path), scheme))
    p2 = init_params(cfg, jax.random.key(1))      # different init
    o2 = adamw_init(opt_cfg, p2)
    rec = restore_state(mgr2, p2, o2)
    assert rec is not None
    ver, p2, o2, data_state = rec
    assert ver == 6
    # restored params equal the live ones
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert err == 0.0
    # training continues from the restored state without loss blow-up
    data2 = SyntheticLMDataset(cfg.vocab, 16, 2)
    data2.restore(data_state)
    batch = {k: jnp.asarray(v) for k, v in data2.next_batch().items()}
    _, _, m = step(p2, o2, batch)
    assert abs(float(m["loss"]) - losses[-1]) < 1.0
    mgr2.close()


def test_restore_prefers_buffer_forwarding(tmp_path):
    """RF: a restore right after persist is served by the buffer tier."""
    buf = HostBufferTier(capacity_bytes=64 << 20)
    store = DurableStore(str(tmp_path / "s"), write_delay_s=0.05)
    mgr = PCSCheckpointManager(buf, store, scheme=PersistScheme.PB_RF)
    mgr.persist("w", 1, np.ones(1000))
    got = mgr.restore("w")                        # store write still in flight
    assert got[0] == 1
    assert mgr.stats["restore_forwarded"] == 1
    mgr.close()


@pytest.mark.slow
def test_cli_train_runs(tmp_path):
    """The launcher CLI end-to-end (smallest smoke config)."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "smollm-135m", "--smoke", "--steps", "4", "--batch", "2",
           "--seq", "16", "--ckpt-every", "2",
           "--ckpt-dir", str(tmp_path / "ck"), "--store-delay-ms", "1"]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=300,
                         env=env, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "train done" in out.stdout
