"""First-class QoS/drain-policy API (ISSUE 4).

Covers the three acceptance properties of the policy redesign:
  (a) the global ``SimResult`` stays a bit-exact row-sum of the tenant
      rows under any quota policy;
  (b) quota validity (one entry per tenant, sum <= n_pbe, entries >= 1)
      is enforced at construction;
  (c) the default ``PBPolicy`` reproduces the legacy-knob configs
      bit-exactly — the compat guard pinning PR 3's results — including
      as a cell inside a mixed-policy grid.

Plus oracle-level QoS semantics: the quota occupancy bound (disjoint
address spaces, where no coalesce takeover can inflate occupancy) and
the tenant-scoped drain-down protecting a quiet tenant's Dirty entries.
"""
import numpy as np
import pytest

from conftest import TINY_BUCKET
from repro.core import (AllocPolicy, DrainPolicy, PBPolicy, PCSConfig,
                        Scheme, make_tenant_trace, simulate, simulate_grid)
from repro.core.engine import compile_count
from repro.core.engine.state import scalars_from_config
from repro.core.params import PBEState, tenant_drain_counts
from repro.core.semantics import PersistentBuffer

COUNT_FIELDS = ("persists", "pm_reads", "read_hits", "coalesces",
                "pm_writes", "pi_detours", "victim_drains",
                "acked_persists", "durable_persists")
FLOAT_FIELDS = ("runtime_ns", "persist_lat_ns", "read_lat_ns", "stall_ns")

TENANT_BUDGET = 60

QUOTA_POLICIES = [
    PBPolicy(alloc=AllocPolicy(tenant_quota=(8, 8))),
    PBPolicy(alloc=AllocPolicy(victim="weighted", tenant_quota=(4, 12))),
    PBPolicy(drain=DrainPolicy(per_tenant=True),
             alloc=AllocPolicy(tenant_quota=(4, 4))),
]


@pytest.fixture(scope="module")
def two_tenant_trace():
    return make_tenant_trace("radiosity", 2, 2,
                             persist_budget=TENANT_BUDGET)


# ---------------------------------------------------------------------------
# (b) construction-time validation
# ---------------------------------------------------------------------------

def test_quota_sum_validated_at_construction():
    pol = PBPolicy(alloc=AllocPolicy(tenant_quota=(10, 10)))
    with pytest.raises(ValueError, match="sum"):
        PCSConfig(scheme=Scheme.PB, n_tenants=2, n_cores=4, policy=pol)


def test_quota_arity_must_match_tenants():
    pol = PBPolicy(alloc=AllocPolicy(tenant_quota=(4, 4, 4)))
    with pytest.raises(ValueError, match="one per tenant"):
        PCSConfig(scheme=Scheme.PB, n_tenants=2, n_cores=4, policy=pol)


def test_quota_entries_positive():
    with pytest.raises(ValueError, match=">= 1"):
        AllocPolicy(tenant_quota=(0, 4))


def test_victim_mode_validated():
    with pytest.raises(ValueError, match="victim"):
        AllocPolicy(victim="round_robin")


def test_drain_fractions_validated():
    with pytest.raises(ValueError, match="preset"):
        DrainPolicy(threshold=0.5, preset=0.7)


def test_tenant_drain_counts_anchor_on_quota_or_fair_share():
    pol = PBPolicy(drain=DrainPolicy(per_tenant=True),
                   alloc=AllocPolicy(tenant_quota=(2, 6)))
    assert tenant_drain_counts(pol, 16, 2) == [(2, 1), (5, 3)]
    fair = PBPolicy(drain=DrainPolicy(per_tenant=True))
    # fair share 16/2 = 8 per tenant
    assert tenant_drain_counts(fair, 16, 2) == [(7, 4), (7, 4)]


# ---------------------------------------------------------------------------
# (c) compat guard: the default policy is the legacy behaviour, bit-exactly
# ---------------------------------------------------------------------------

def test_legacy_knobs_forward_into_policy():
    cfg = PCSConfig(scheme=Scheme.PB_RF, drain_threshold=0.7,
                    drain_preset=0.5)
    assert cfg.policy.drain.threshold == 0.7
    assert cfg.policy.drain.preset == 0.5
    # and policy= wins over the floats (one source of truth)
    pol = PBPolicy(drain=DrainPolicy(threshold=0.9, preset=0.4))
    cfg2 = PCSConfig(scheme=Scheme.PB_RF, drain_threshold=0.7,
                     drain_preset=0.5, policy=pol)
    assert cfg2.drain_threshold == 0.9 and cfg2.drain_preset == 0.4


def test_default_policy_lowering_identical():
    """Legacy-knob and explicit-default configs lower to the same traced
    scalars — the strongest form of the bit-exactness guarantee."""
    legacy = PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2)
    explicit = PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2,
                         policy=PBPolicy())
    a = scalars_from_config(legacy, 2)
    b = scalars_from_config(explicit, 2)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


def test_default_policy_bit_exact_inside_mixed_policy_grid(two_tenant_trace):
    """A legacy-knob config, an explicit default-policy config and a
    quota-policy config share ONE compiled grid; the first two cells are
    bit-identical (PR 3 compat), and the legacy cell matches its
    standalone run."""
    tr = two_tenant_trace
    cfgs = [PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2),
            PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2,
                      policy=PBPolicy()),
            PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2,
                      policy=QUOTA_POLICIES[1])]
    c0 = compile_count()
    cells = simulate_grid([tr], cfgs, bucket=TINY_BUCKET)[0]
    assert compile_count() - c0 == 1, (
        "mixed-policy grid must lower to one XLA program")
    for f in COUNT_FIELDS + FLOAT_FIELDS:
        assert getattr(cells[0], f) == getattr(cells[1], f), f
    np.testing.assert_array_equal(cells[0].tenant_stats,
                                  cells[1].tenant_stats)
    # and the legacy cell equals its standalone (pre-policy API) run
    solo = simulate(tr, cfgs[0], bucket=TINY_BUCKET)
    for f in COUNT_FIELDS:
        assert getattr(cells[0], f) == getattr(solo, f), f
    for f in FLOAT_FIELDS:
        assert getattr(cells[0], f) == pytest.approx(
            getattr(solo, f), rel=1e-15), f


# ---------------------------------------------------------------------------
# (a) global = bit-exact row sum of tenant rows, under any quota policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol_idx", range(len(QUOTA_POLICIES)))
def test_global_is_row_sum_under_quota_policy(two_tenant_trace, pol_idx):
    pol = QUOTA_POLICIES[pol_idx]
    r = simulate(two_tenant_trace,
                 PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2,
                           policy=pol),
                 bucket=TINY_BUCKET)
    assert r.tenant_stats is not None
    rows = r.tenant_results()
    for f in COUNT_FIELDS:
        assert sum(getattr(t, f) for t in rows) == getattr(r, f), (pol, f)
    assert sum(t.stall_ns for t in rows) == pytest.approx(r.stall_ns)
    # raw matrix row-sum is bit-exact against the global accumulators
    tot = np.asarray(r.tenant_stats).sum(axis=0)
    assert int(tot[0] >= 0)  # matrix well-formed
    assert r.persists == int(tot[1])


def test_quota_policy_changes_allocation(two_tenant_trace):
    """A binding quota visibly engages the victim/recycle path."""
    base = simulate(two_tenant_trace,
                    PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2),
                    bucket=TINY_BUCKET)
    tight = simulate(two_tenant_trace,
                     PCSConfig(scheme=Scheme.PB_RF, n_cores=4, n_tenants=2,
                               policy=PBPolicy(alloc=AllocPolicy(
                                   victim="weighted", tenant_quota=(2, 2)))),
                     bucket=TINY_BUCKET)
    assert base.victim_drains == 0
    assert tight.victim_drains > 0
    # same offered work either way
    assert tight.persists == base.persists


# ---------------------------------------------------------------------------
# Oracle-level QoS semantics
# ---------------------------------------------------------------------------

def test_oracle_quota_occupancy_bound():
    """With disjoint per-tenant address spaces (no coalesce takeover), a
    tenant's live-entry occupancy never exceeds its quota."""
    import random
    rng = random.Random(11)
    quota = (2, 3)
    pb = PersistentBuffer(PCSConfig(
        scheme=Scheme.PB_RF, n_pbe=8, n_tenants=2, n_cores=4,
        policy=PBPolicy(alloc=AllocPolicy(tenant_quota=quota))))
    pending = []
    for i in range(300):
        t = rng.randrange(2)
        addr = 100 * t + rng.randrange(12)      # disjoint address spaces
        evs = pb.persist(addr, f"v{i}", tenant=t)
        pending += [(e.addr, e.version) for e in evs
                    if e.kind.name == "DRAIN_SENT"]
        if rng.random() < 0.6:
            while pending:
                a, v = pending.pop(0)
                evs = pb.pm_ack(a, v)
                pending += [(e.addr, e.version) for e in evs
                            if e.kind.name == "DRAIN_SENT"]
        for tt in range(2):
            occ = sum(1 for e in pb.entries
                      if e.state != PBEState.EMPTY and e.tenant == tt)
            assert occ <= quota[tt], (i, tt, occ)
        pb.check_invariants()


def test_oracle_tenant_scoped_drain_protects_quiet_tenant():
    """Under ``DrainPolicy(per_tenant=True)`` a noisy tenant's drain-down
    drains only its own entries: the quiet tenant's Dirty entries stay
    buffered.  Under the default global policy the same load evicts
    them (they are the LRU Dirty entries)."""
    def run(per_tenant):
        pol = PBPolicy(drain=DrainPolicy(per_tenant=per_tenant))
        pb = PersistentBuffer(PCSConfig(
            scheme=Scheme.PB_RF, n_pbe=8, n_tenants=2, n_cores=4,
            policy=pol))
        # quiet tenant 1 parks two Dirty lines, then goes idle
        pb.persist(100, "q0", tenant=1)
        pb.persist(101, "q1", tenant=1)
        # noisy tenant 0 streams distinct lines, drains resolve promptly
        pending = []
        for i in range(12):
            evs = pb.persist(i, f"n{i}", tenant=0)
            pending += [(e.addr, e.version) for e in evs
                        if e.kind.name == "DRAIN_SENT"]
            while pending:
                a, v = pending.pop(0)
                evs = pb.pm_ack(a, v)
                pending += [(e.addr, e.version) for e in evs
                            if e.kind.name == "DRAIN_SENT"]
        return {e.addr for e in pb.entries
                if e.tenant == 1 and e.state == PBEState.DIRTY}
    assert run(per_tenant=True) == {100, 101}, (
        "tenant-scoped drain-down must not evict the quiet tenant")
    assert run(per_tenant=False) != {100, 101}, (
        "global drain-down is expected to evict the quiet tenant's LRU "
        "entries (otherwise this test guards nothing)")
