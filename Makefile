PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench-smoke bench calibrate

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# each figure on a tiny trace (<60s); writes BENCH_engine.json
bench-smoke:
	$(PYTHON) -m benchmarks.run --smoke

# full paper-budget benchmark CSV
bench:
	$(PYTHON) -m benchmarks.run

calibrate:
	$(PYTHON) -m benchmarks._calibrate
