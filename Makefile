PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: lint test test-all test-fuzz bench-smoke bench bench-compare calibrate ci

# static invariant analysis (repro.analysis): retrace-hazard, mirror-site,
# oracle-twin, dtype-packing and sweep-registry passes; writes the
# findings summary to ANALYSIS.json and fails on any finding
lint:
	$(PYTHON) -m repro.analysis --fail-on-findings --json ANALYSIS.json

# fast suite (<1 min): everything except the @slow big-model smokes and
# exhaustive grids
test:
	$(PYTHON) -m pytest -x -q -m "not slow"

# tier-1 verify (ROADMAP.md): the whole suite, slow tests included
test-all:
	$(PYTHON) -m pytest -x -q

# differential crash-point conformance fuzzing at a raised budget
# (engine <-> oracle; see tests/test_crash_differential.py)
test-fuzz:
	CRASH_FUZZ_SEEDS=20 CRASH_FUZZ_EXAMPLES=150 \
	$(PYTHON) -m pytest -x -q tests/test_crash_differential.py

# each figure on a tiny trace (<60s); writes BENCH_engine.json
bench-smoke:
	$(PYTHON) -m benchmarks.run --smoke

# regenerate the smoke report and diff it against the committed
# baseline (git show HEAD:BENCH_engine.json): prints per-sweep speedup
# ratios, fails on a >1.25x regression of ANY numeric *_wall_s
# (total_wall_s included); rows without a numeric baseline warn
bench-compare: bench-smoke
	$(PYTHON) -m benchmarks.compare

# full paper-budget benchmark CSV
bench:
	$(PYTHON) -m benchmarks.run

calibrate:
	$(PYTHON) -m benchmarks._calibrate

# CI lane: static invariant analysis first (seconds; fails fast on a
# broken contract), then fast tests (including the depth differential's
# fast chain matrix; the >=500-cell depth-4 matrix runs behind the
# `slow` marker in `test-all`), then the smoke benchmarks + wall-clock
# regression diff
# against the committed report (benchmarks/compare.py), then the
# compile-count regression guard (the shared grid / recovery sweep /
# tenant sweep / QoS sweep / chain depth sweep must each stay exactly
# ONE XLA program, macro-stepping enabled, with per-sweep macro hit
# rates recorded — see benchmarks/check_compiles.py)
ci: lint test bench-compare
	$(PYTHON) -m benchmarks.check_compiles
