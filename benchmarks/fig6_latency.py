"""Fig 6: persist and read latencies (from LLC) per scheme, normalized to
NoPB.  Paper: PB cuts persist latency 43-56%; read latency rises 2.5-12%.

Cells come from the shared one-program {workload x scheme} grid
(`_shared.result` -> `simulate_grid`)."""
from __future__ import annotations

from repro.core import Scheme

from benchmarks._shared import emit, result, workloads


def run() -> list:
    rows = []
    for name in workloads():
        nopb = result(name, Scheme.NOPB)
        for key, scheme in (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF)):
            r = result(name, scheme)
            rows.append((f"fig6a_persist_{key}_{name}",
                         round(100 * r.persist_lat_ns / nopb.persist_lat_ns, 1),
                         "pct_of_nopb"))
            rows.append((f"fig6b_read_{key}_{name}",
                         round(100 * r.read_lat_ns / nopb.read_lat_ns, 1),
                         "pct_of_nopb"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
