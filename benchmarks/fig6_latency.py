"""Fig 6: persist and read latencies (from LLC) per scheme, normalized to
NoPB.  Paper: PB cuts persist latency 43-56%; read latency rises 2.5-12%.

Cells come from the shared one-program {workload x scheme} grid
(`_shared.result` -> `simulate_grid`)."""
from __future__ import annotations

import math

from repro.core import Scheme

from benchmarks._shared import emit, result, workloads


# consumes the cached one-program {workload x scheme} grid: wall
# time excludes the grid build whenever another figure paid for it
REUSES_SHARED_GRID = True


def run() -> list:
    rows = []
    for name in workloads():
        nopb = result(name, Scheme.NOPB)
        for key, scheme in (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF)):
            r = result(name, scheme)
            # empty means are NaN (no persists/reads in the cell) — skip
            # rather than emit a meaningless normalized row
            if not (math.isnan(r.persist_lat_ns)
                    or math.isnan(nopb.persist_lat_ns)):
                rows.append((f"fig6a_persist_{key}_{name}",
                             round(100 * r.persist_lat_ns
                                   / nopb.persist_lat_ns, 1),
                             "pct_of_nopb"))
            if not (math.isnan(r.read_lat_ns)
                    or math.isnan(nopb.read_lat_ns)):
                rows.append((f"fig6b_read_{key}_{name}",
                             round(100 * r.read_lat_ns / nopb.read_lat_ns,
                                   1),
                             "pct_of_nopb"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
