"""Render the EXPERIMENTS.md roofline table from dry-run JSON output.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_single.json
"""
from __future__ import annotations

import json
import sys

from repro.configs import get_config
from repro.launch.shapes import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def advice(row: dict) -> str:
    b = row["bottleneck"]
    if b == "collective":
        ag = row["collectives"].get("all-gather", 0)
        ar = row["collectives"].get("all-reduce", 0)
        rs = row["collectives"].get("reduce-scatter", 0)
        big = max([("all-gather", ag), ("all-reduce", ar),
                   ("reduce-scatter", rs)], key=lambda kv: kv[1])[0]
        return (f"dominated by {big}s — overlap weight gathers with compute "
                f"or re-shard to cut resharding traffic")
    if b == "memory":
        return "HBM-bound — raise arithmetic intensity (fuse, larger blocks)"
    return "compute-bound — already near the MXU roof; tune block shapes"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single.json"
    rows = json.load(open(path))
    print("| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | "
          "bound | model/HLO flops | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok":
            tag = "skip" if "skipped" in r["status"] else "FAIL"
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                  f"{tag} | - | - | {r['status'][:60]} |")
            continue
        mf = model_flops_per_device(r["arch"], r["shape"], r["chips"])
        ratio = mf / max(r["flops_per_device"], 1)
        tc, tm, tl = (r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        step = max(tc, tm, tl)
        frac = (mf / PEAK_FLOPS) / step if step > 0 else 0.0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {tc:.3g} | "
              f"{tm:.3g} | {tl:.3g} | {r['bottleneck']} | {ratio:.2f} | "
              f"{frac:.1%} | {advice(r)} |")


if __name__ == "__main__":
    main()
