"""Engine perf trajectory guard: fresh vs committed BENCH_engine.json.

``make bench-compare`` regenerates the smoke report and diffs it against
the committed baseline (``git show HEAD:BENCH_engine.json`` by default,
so it works even though ``bench-smoke`` overwrites the working-tree
copy).  It prints a per-key speedup ratio for **every numeric top-level
``*_wall_s``** in the fresh report (sweeps, the shared grid, the total)
and **fails** when any of them regressed by more than ``THRESHOLD``x —
``*_compile_s`` keys are deliberately OUTSIDE the gate (the
``endswith("_wall_s")`` filter excludes them): compile latency is
tracked for visibility, but only the warm-run component may fail CI —
wall-clock noise on a quiet machine is far below 25%, so a trip means a
real perf regression (e.g. a change that breaks the macro-step guards,
widens the packed dtypes, or defeats the chunked early exit).  Keys
that cannot be compared (no numeric baseline — e.g. a sweep new in this
PR — or a non-positive wall time) are reported as loud ``warn:`` lines
rather than silently dropped, and their wall time is discounted from
the ``total_wall_s`` comparison (a newly added figure grows the total
legitimately; the per-sweep keys still gate every pre-existing sweep).

Reports are only comparable at the same measurement budget: when the
budget/bucket/smoke fields differ the comparison is skipped with a
warning instead of producing nonsense ratios.

    PYTHONPATH=src python -m benchmarks.compare [fresh] [baseline]

``fresh`` defaults to ``BENCH_engine.json``; ``baseline`` defaults to
the HEAD copy via git (pass a path to diff two files directly).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

THRESHOLD = 1.25     # fail when fresh wall > 1.25x the committed wall
# ``timing`` is the measurement methodology (cold/warm split vs the old
# single-run wall): reports measured differently aren't ratio-comparable
BUDGET_KEYS = ("smoke", "budget", "bucket", "timing")


def _load_baseline(ref: str) -> dict:
    """Baseline report: a file path, or ``git:REF`` for a committed copy."""
    if ref.startswith("git:"):
        blob = subprocess.run(
            ["git", "show", f"{ref[4:]}:BENCH_engine.json"],
            capture_output=True, text=True, check=True).stdout
        return json.loads(blob)
    with open(ref) as f:
        return json.load(f)


def wall_keys(fresh: dict) -> list:
    """Every top-level *numeric* ``*_wall_s`` key in the fresh report.

    ``figures_wall_s`` (a dict of per-figure timings) is excluded by the
    numeric filter; its entries are already rolled up in the sweep keys
    and ``total_wall_s``.
    """
    return sorted(k for k in fresh if k.endswith("_wall_s")
                  and isinstance(fresh[k], (int, float)))


def compare(fresh: dict, base: dict) -> tuple:
    """Returns ``(lines, regressions)`` — human lines and failed keys."""
    mismatched = [k for k in BUDGET_KEYS if fresh.get(k) != base.get(k)]
    if mismatched:
        return ([f"skip: budgets differ ({', '.join(mismatched)}); "
                 "ratios would compare different workloads or "
                 "measurement methodologies"], [])
    lines, regressions = [], []
    # a sweep new in this PR has no baseline to regress against, but its
    # wall time still lands inside total_wall_s — discount it there so a
    # legitimately added figure doesn't read as a whole-run regression
    new_sweep_s = sum(
        float(fresh[k]) for k in wall_keys(fresh)
        if k != "total_wall_s"
        and not isinstance(base.get(k), (int, float)))
    for k in wall_keys(fresh):
        f_v = float(fresh[k])
        b = base.get(k)
        if not isinstance(b, (int, float)):
            lines.append(f"warn: {k} has no numeric baseline ({b!r}); "
                         "not compared (expected for a sweep new in "
                         "this PR)")
            continue
        b_v = float(b)
        note = ""
        if k == "total_wall_s" and new_sweep_s > 0:
            f_v = max(f_v - new_sweep_s, 0.0)
            note = f" (excl. {new_sweep_s:.1f}s of new sweeps)"
        if f_v <= 0 or b_v <= 0:
            lines.append(f"warn: {k} skipped — non-positive wall time "
                         f"(fresh={f_v}, base={b_v}) cannot be ratioed")
            continue
        speedup = b_v / f_v
        verdict = "ok"
        if f_v > THRESHOLD * b_v:
            verdict = f"REGRESSION (> {THRESHOLD}x)"
            regressions.append(k)
        lines.append(f"{k}: {b_v:.3f}s -> {f_v:.3f}s "
                     f"({speedup:.2f}x speedup) {verdict}{note}")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="?", default="BENCH_engine.json")
    ap.add_argument("baseline", nargs="?", default="git:HEAD")
    args = ap.parse_args(argv)
    try:
        fresh = json.load(open(args.fresh))
    except OSError as e:
        print(f"bench-compare: cannot read {args.fresh}: {e}",
              file=sys.stderr)
        return 2
    try:
        base = _load_baseline(args.baseline)
    except (OSError, subprocess.CalledProcessError, json.JSONDecodeError):
        # no committed baseline (first PR with a report, shallow clone):
        # nothing to regress against — succeed loudly, don't block CI
        print("bench-compare: no readable baseline "
              f"({args.baseline}); skipping comparison")
        return 0
    lines, regressions = compare(fresh, base)
    for ln in lines:
        print(f"bench-compare: {ln}")
    if regressions:
        print(f"bench-compare: FAIL {len(regressions)} sweep(s) regressed "
              f"beyond {THRESHOLD}x: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
