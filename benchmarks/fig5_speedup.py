"""Fig 5: speedup of PB and PB_RF over NoPB per workload (+ the paper's
headline 12% / 15% means).

Cells come from the shared one-program {workload x scheme} grid
(`_shared.result` -> `simulate_grid`): one XLA compilation for all 21
cells, scheme traced."""
from __future__ import annotations

from repro.core import Scheme

from benchmarks._shared import emit, result, workloads

# consumes the cached one-program {workload x scheme} grid: wall
# time excludes the grid build whenever another figure paid for it
REUSES_SHARED_GRID = True


PAPER_MEAN = {"pb": 12.0, "pb_rf": 15.0}


def run() -> list:
    rows = []
    sp = {"pb": [], "pb_rf": []}
    for name in workloads():
        nopb = result(name, Scheme.NOPB)
        for key, scheme in (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF)):
            r = result(name, scheme)
            s = 100.0 * (nopb.runtime_ns / r.runtime_ns - 1.0)
            sp[key].append(s)
            rows.append((f"fig5_{key}_{name}", round(s, 1), "speedup_%"))
    for key in ("pb", "pb_rf"):
        mean = sum(sp[key]) / len(sp[key])
        rows.append((f"fig5_{key}_mean", round(mean, 1),
                     f"paper={PAPER_MEAN[key]}%"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
