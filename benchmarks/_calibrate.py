"""Calibration harness: all workloads x schemes vs paper targets.

Not part of the benchmark suite proper — used during development to tune
the trace generators, and kept for reproducibility of the calibration.
Run: PYTHONPATH=src python -m benchmarks._calibrate
"""
import time

from repro.core import PCSConfig, Scheme, WORKLOADS, make_trace, simulate

# (PB speedup %, RF speedup %, RF hit %, RF coalesce %) from paper Figs 5/7
PAPER = {
    "radiosity":   (22, 40, 51, 50),
    "lu_non":      (22, 40, 20, 20),
    "lu_cont":     (12, 18, 20, 20),
    "raytrace":    (10, 14, 20, 20),
    "fft":         (3, -2, 20, 2.8),
    "cholesky":    (-3, -13, 1, 1),
    "volrend_npl": (0, -2, 1, 1),
}


def main() -> None:
    rows = []
    for name in WORKLOADS:
        t0 = time.time()
        tr = make_trace(name)
        res = {s: simulate(tr, PCSConfig(scheme=s))
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)}
        nopb, pb, rf = res[Scheme.NOPB], res[Scheme.PB], res[Scheme.PB_RF]
        sp_pb = 100 * (nopb.runtime_ns / pb.runtime_ns - 1)
        sp_rf = 100 * (nopb.runtime_ns / rf.runtime_ns - 1)
        plat_pb = 100 * pb.persist_lat_ns / nopb.persist_lat_ns
        plat_rf = 100 * rf.persist_lat_ns / nopb.persist_lat_ns
        rlat_pb = 100 * pb.read_lat_ns / nopb.read_lat_ns
        rlat_rf = 100 * rf.read_lat_ns / nopb.read_lat_ns
        tgt = PAPER[name]
        rows.append(
            f"{name:12s} PB {sp_pb:+6.1f}% (paper {tgt[0]:+3d}%)  "
            f"RF {sp_rf:+6.1f}% (paper {tgt[1]:+3d}%)  "
            f"hit {100*rf.read_hit_rate:5.1f}% (paper {tgt[2]:4.1f}%)  "
            f"coal {100*rf.coalesce_rate:5.1f}% (paper {tgt[3]:4.1f}%)  "
            f"plat {plat_pb:3.0f}/{plat_rf:3.0f}%  rlat {rlat_pb:3.0f}/{rlat_rf:3.0f}%  "
            f"[{time.time()-t0:5.1f}s ops={tr.total_ops}]")
        print(rows[-1], flush=True)
    print("\nsummary:")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
