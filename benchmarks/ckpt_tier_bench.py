"""Layer-B benchmark: the PCS idea at cluster scale (checkpoint tiers).

Measures, per scheme, the persist latency seen by the training loop
(the "fence" the step blocks on) and the restore path, with a slow
durable store standing in for an object store.  The cluster-scale
analogue of Figs 5/6: ack-at-buffer cuts persist latency by ~the
store/buffer latency ratio; RF serves restores from the buffer.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)

from benchmarks._shared import emit

SHARD_KB = 256
N_SHARDS = 24
N_VERSIONS = 4
STORE_DELAY_S = 0.01


def _run(scheme: PersistScheme):
    with tempfile.TemporaryDirectory() as d:
        buf = HostBufferTier(capacity_bytes=512 << 20)
        store = DurableStore(d + "/s", write_delay_s=STORE_DELAY_S)
        mgr = PCSCheckpointManager(buf, store, scheme=scheme)
        payload = np.zeros(SHARD_KB * 256, np.float32)  # SHARD_KB KiB
        t_persist = 0.0
        for v in range(1, N_VERSIONS + 1):
            t0 = time.time()
            for i in range(N_SHARDS):
                mgr.persist(f"shard{i}", v, payload)
            t_persist += time.time() - t0
        # restore immediately (RF window)
        t0 = time.time()
        fwd = 0
        for i in range(N_SHARDS):
            mgr.restore(f"shard{i}")
        t_restore = time.time() - t0
        fwd = mgr.stats["restore_forwarded"]
        coal = mgr.stats["coalesces"]
        mgr.close()
        per = 1e6 * t_persist / (N_SHARDS * N_VERSIONS)
        return per, 1e6 * t_restore / N_SHARDS, fwd, coal


def run() -> list:
    rows = []
    base = None
    for scheme in (PersistScheme.NOPB, PersistScheme.PB, PersistScheme.PB_RF):
        per, res, fwd, coal = _run(scheme)
        if base is None:
            base = per
        rows.append((f"ckpt_{scheme.value}_persist_us", round(per, 1),
                     f"norm={per / base:.2f}x"))
        rows.append((f"ckpt_{scheme.value}_restore_us", round(res, 1),
                     f"forwarded={fwd} coalesced={coal}"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
