"""Offered-load saturation sweep: tail latency (P50/P95/P99) vs load.

The paper's headline numbers are *mean*-latency wins, but a serving
deployment (ROADMAP: pooled switches in front of millions of users)
lives on tails: the persist that queues behind a drain burst is exactly
the P99 event an SLO cares about.  This figure drives one workload's
op/address stream with **open-loop Poisson arrivals** at a sweep of
offered loads (``core.traces.make_offered_load_trace``), plus one
bursty (on-off) point at the mid rate, and reads the per-persist
latency histogram the engine now accumulates per tenant
(``SimResult.persist_lat_p50/p95/p99``):

  * below the saturation knee the percentiles sit flat at the service
    latency; past it the PBC/PM queues grow without bound and the tail
    explodes — the knee rate per {scheme x policy} is the serving
    capacity of the switch;
  * the ``pb_rf_slo`` config closes the loop with
    ``DrainPolicy(latency_target_ns=...)``: when the observed running
    tail exceeds target, drain-down tightens to drain-everything-ASAP.

The whole {offered-load x scheme x policy} sweep is ONE
``simulate_grid`` call — arrival processes are a *trace* axis, so they
compose with the traced config axes for free (the
``slo_sweep_compiles`` guard in ``make ci`` pins this).
"""
from __future__ import annotations

import math

from repro.core import (BurstyArrivals, DrainPolicy, PBPolicy, PCSConfig,
                        PoissonArrivals, Scheme, make_offered_load_trace,
                        simulate_grid)

from benchmarks import _shared

WORKLOAD = "raytrace"
# Serving pressure needs enough cores behind one switch to saturate the
# shared PBC (20 ns occupancy) and PM banks: each blocked core offers at
# most ~1/300ns, so 64 cores push one request every ~5 ns at full load —
# well past the service rate, where the queue (and the tail) grows.
N_CORES = 64

# offered load axis, Mops/s per core; smoke keeps enough points to see
# the knee while staying inside the <60s budget
RATES_FULL = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
RATES_SMOKE = (0.25, 1.0, 4.0, 16.0)

# knee = first rate whose P99 exceeds KNEE x the lowest-rate P99.  1.8
# keeps the drain-immediately PB scheme's shallow saturation visible
# (its tail roughly doubles while NOPB's and lazy PB_RF's explode).
KNEE = 1.8

CONFIGS = (
    ("nopb", Scheme.NOPB, PBPolicy()),
    ("pb", Scheme.PB, PBPolicy()),
    ("pb_rf", Scheme.PB_RF, PBPolicy()),
    ("pb_rf_slo", Scheme.PB_RF, PBPolicy(drain=DrainPolicy(
        latency_target_ns=450.0, latency_tol=0.05))),
)

# telemetry of the SLO sweep for BENCH_engine.json (set by run())
sweep_metrics: dict = {}


def run() -> list:
    rates = RATES_SMOKE if _shared.SMOKE else RATES_FULL
    budget = max(_shared.BUDGET // 4, 150)
    traces = [make_offered_load_trace(
                  WORKLOAD, PoissonArrivals(r), n_cores=N_CORES,
                  persist_budget=budget)
              for r in rates]
    # one bursty point at the mid rate: same time-average offered load,
    # fatter tail (the on-phase runs burst-x hotter)
    mid = rates[len(rates) // 2]
    traces.append(make_offered_load_trace(
        WORKLOAD, BurstyArrivals(mid), n_cores=N_CORES,
        persist_budget=budget))
    configs = [PCSConfig(scheme=s, n_cores=N_CORES, policy=pol)
               for _, s, pol in CONFIGS]
    cells, m = _shared.timed_sweep(
        lambda: simulate_grid(traces, configs, bucket=_shared.bucket()))
    sweep_metrics.update(
        slo_sweep_wall_s=m["wall_s"],
        slo_sweep_compile_s=m["compile_s"],
        slo_sweep_compiles=m["compiles"],
        slo_sweep_cells=len(traces) * len(configs),
        slo_sweep_macro_hit=m["macro_hit"],
        slo_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    p99_series = {ckey: [] for ckey, _, _ in CONFIGS}
    for rate, row in zip(rates, cells):
        for (ckey, _, _), r in zip(CONFIGS, row):
            if math.isnan(r.persist_lat_p50):
                continue            # zero-traffic cell: no percentiles
            rows.append((f"slo_p50_{ckey}_{rate:g}",
                         round(r.persist_lat_p50, 1), "ns"))
            rows.append((f"slo_p95_{ckey}_{rate:g}",
                         round(r.persist_lat_p95, 1), "ns"))
            rows.append((f"slo_p99_{ckey}_{rate:g}",
                         round(r.persist_lat_p99, 1), "ns"))
            p99_series[ckey].append((rate, r.persist_lat_p99))
    for (ckey, _, _), r in zip(CONFIGS, cells[len(rates)]):
        if not math.isnan(r.persist_lat_p99):
            rows.append((f"slo_p99_{ckey}_bursty{mid:g}",
                         round(r.persist_lat_p99, 1), "ns"))
    # the saturation knee (NaN = no knee inside the swept range)
    for ckey, series in p99_series.items():
        if not series:
            continue
        base = series[0][1]
        knee = next((rate for rate, p99 in series if p99 > KNEE * base),
                    float("nan"))
        rows.append((f"slo_knee_{ckey}", knee, "mops_per_core"))
    # SLO accounting at the hottest rate; only configs with a target
    # count violations (nothing is ever over the default +inf target)
    top = cells[len(rates) - 1]
    for (ckey, _, pol), r in zip(CONFIGS, top):
        if pol.drain.latency_target_ns is not None and r.persists > 0:
            rows.append((f"slo_viol_{ckey}_{rates[-1]:g}",
                         round(r.slo_violations / r.persists, 4),
                         "over_450ns_fraction"))
    return rows


def main() -> None:
    _shared.emit(run())


if __name__ == "__main__":
    main()
