"""Re-run the §Perf hillclimbed cells: baseline vs optimized layout.

    PYTHONPATH=src python -m benchmarks.perf_cells          # ~10 min (compiles)

Prints the roofline terms for each of the three chosen cells under the
baseline layout and under the winning layout from EXPERIMENTS.md §Perf,
so the before/after table is reproducible from source.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

CELLS = [
    # (arch, shape, optimized FLAGS, microbatches)
    ("deepseek-67b", "train_4k",
     {"fsdp_same_dim": True, "batch_both": True}, 1),
    ("jamba-1.5-large-398b", "train_4k", {}, 1),   # grouped dispatch is in-model
    ("mixtral-8x7b", "prefill_32k", {}, 1),        # negative result: baseline
]


def main() -> None:
    from repro.launch import sharding as sh
    from repro.launch import dryrun as dr

    print("name,t_compute_s,t_memory_s,t_collective_s,bottleneck")
    for arch, shape, flags, mb in CELLS:
        for label, f in (("baseline", {}), ("optimized", flags)):
            saved = dict(sh.FLAGS)
            sh.FLAGS.update(f)
            dr.MICROBATCHES[0] = mb if label == "optimized" else 1
            try:
                r = dr.run_cell(arch, shape, False, verbose=False)
                print(f"perf_{arch}_{shape}_{label},"
                      f"{r['t_compute_s']:.3g},{r['t_memory_s']:.3g},"
                      f"{r['t_collective_s']:.3g},{r['bottleneck']}",
                      flush=True)
            finally:
                sh.FLAGS.clear()
                sh.FLAGS.update(saved)
                dr.MICROBATCHES[0] = 1


if __name__ == "__main__":
    main()
