"""Re-run the §Perf hillclimbed cells: baseline vs optimized layout.

    PYTHONPATH=src python -m benchmarks.perf_cells             # both sections
    PYTHONPATH=src python -m benchmarks.perf_cells --pcs       # engine only
    PYTHONPATH=src python -m benchmarks.perf_cells --roofline  # roofline only

Two sections:
  * ``pcs_grid_cells`` — the PCS engine hot path: per-cell ``simulate``
    loop vs the one-program ``simulate_grid`` on the same mixed-scheme
    {workload x scheme} grid, with wall times and XLA compile counts.
  * roofline terms for the three launch/dryrun cells under the baseline
    layout and the winning layout from EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import os
import time

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

CELLS = [
    # (arch, shape, optimized FLAGS, microbatches)
    ("deepseek-67b", "train_4k",
     {"fsdp_same_dim": True, "batch_both": True}, 1),
    ("jamba-1.5-large-398b", "train_4k", {}, 1),   # grouped dispatch is in-model
    ("mixtral-8x7b", "prefill_32k", {}, 1),        # negative result: baseline
]

PCS_NAMES = ("radiosity", "cholesky", "raytrace")
PCS_BUDGET = 2_000
PCS_BUCKET = 4096


def pcs_grid_cells() -> None:
    """Sequential per-cell simulate vs the batched one-program grid."""
    from repro.core import PCSConfig, Scheme, make_trace
    from repro.core.engine import compile_count, simulate, simulate_grid

    traces = [make_trace(n, persist_budget=PCS_BUDGET) for n in PCS_NAMES]
    configs = [PCSConfig(scheme=s)
               for s in (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)]

    print("name,wall_s,compiles,cells")
    c0, t0 = compile_count(), time.time()
    seq = [[simulate(tr, cfg, bucket=PCS_BUCKET) for cfg in configs]
           for tr in traces]
    print(f"pcs_sequential,{time.time() - t0:.3f},{compile_count() - c0},"
          f"{len(traces) * len(configs)}", flush=True)

    c0, t0 = compile_count(), time.time()
    grid = simulate_grid(traces, configs, bucket=PCS_BUCKET)
    print(f"pcs_grid,{time.time() - t0:.3f},{compile_count() - c0},"
          f"{len(traces) * len(configs)}", flush=True)

    worst = max(abs(a.runtime_ns - b.runtime_ns) / max(b.runtime_ns, 1.0)
                for ra, rb in zip(seq, grid) for a, b in zip(ra, rb))
    print(f"pcs_grid_vs_seq_rel_err,{worst:.3g},-,-", flush=True)


def roofline_cells() -> None:
    from repro.launch import sharding as sh
    from repro.launch import dryrun as dr

    print("name,t_compute_s,t_memory_s,t_collective_s,bottleneck")
    for arch, shape, flags, mb in CELLS:
        for label, f in (("baseline", {}), ("optimized", flags)):
            saved = dict(sh.FLAGS)
            sh.FLAGS.update(f)
            dr.MICROBATCHES[0] = mb if label == "optimized" else 1
            try:
                r = dr.run_cell(arch, shape, False, verbose=False)
                print(f"perf_{arch}_{shape}_{label},"
                      f"{r['t_compute_s']:.3g},{r['t_memory_s']:.3g},"
                      f"{r['t_collective_s']:.3g},{r['bottleneck']}",
                      flush=True)
            finally:
                sh.FLAGS.clear()
                sh.FLAGS.update(saved)
                dr.MICROBATCHES[0] = 1


def main() -> None:
    ap = argparse.ArgumentParser()
    section = ap.add_mutually_exclusive_group()
    section.add_argument("--pcs", action="store_true",
                         help="PCS engine cells only")
    section.add_argument("--roofline", action="store_true",
                         help="roofline cells only")
    args = ap.parse_args()
    if not args.roofline:
        pcs_grid_cells()
    if not args.pcs:
        roofline_cells()


if __name__ == "__main__":
    main()
