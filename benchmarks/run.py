"""Run every benchmark; print one ``name,value,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # full paper budget
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time

from benchmarks import (ckpt_tier_bench, fig1_switch_depth, fig5_speedup,
                        fig6_latency, fig7_rf_rates, fig8_pbe_sweep,
                        kernel_bench)
from benchmarks._shared import emit


def main() -> None:
    rows = []
    for mod in (fig1_switch_depth, fig5_speedup, fig6_latency, fig7_rf_rates,
                fig8_pbe_sweep, ckpt_tier_bench, kernel_bench):
        t0 = time.time()
        rows.extend(mod.run())
        rows.append((f"_elapsed_{mod.__name__.split('.')[-1]}",
                     round(time.time() - t0, 1), "seconds"))
    emit(rows)


if __name__ == "__main__":
    main()
