"""Run every benchmark; print one ``name,value,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # full paper budget
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --smoke    # tiny traces, <60s

``--smoke`` runs each figure script on a tiny trace and writes
machine-readable ``BENCH_engine.json`` (per-figure wall time, the shared
grid's wall time and XLA compile count) so the engine perf trajectory is
tracked across PRs.  Each sweep's wall time is the WARM re-run
(``*_wall_s``); XLA compile latency is recorded separately as
``*_compile_s`` so a compile-cache hit can't mask a run regression.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import _shared


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny traces; write BENCH_engine.json")
    # Only smoke runs write BENCH_engine.json by default: the tracked
    # perf trajectory must stay budget-comparable across PRs.  A full
    # run writes a report only when --out is passed explicitly.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None and args.smoke:
        args.out = "BENCH_engine.json"
    if args.smoke:
        _shared.set_smoke()

    # imported late so smoke mode is set before any trace is built
    from benchmarks import (ckpt_tier_bench, fig1_switch_depth, fig5_speedup,
                            fig6_latency, fig7_rf_rates, fig8_pbe_sweep,
                            fig_dynamic, fig_fabric, fig_qos, fig_recovery,
                            fig_slo, fig_tenants, kernel_bench)
    from repro.core.engine import compile_count

    figures = (fig1_switch_depth, fig5_speedup, fig6_latency, fig7_rf_rates,
               fig8_pbe_sweep, fig_recovery, fig_tenants, fig_qos, fig_slo,
               fig_fabric, fig_dynamic)
    extras = () if args.smoke else (ckpt_tier_bench, kernel_bench)

    rows, timings = [], {}
    # Figures sharing the cached {workload x scheme} grid cost ~0 wall
    # seconds when another figure already paid for it; mark them so the
    # perf trajectory cannot misread a reused grid as a free figure.
    # The grid's own wall time is attributed once, under shared_grid_*.
    reused = {}
    t_start = time.time()
    for mod in figures + extras:
        name = mod.__name__.split(".")[-1]
        grid_was_built = bool(_shared.grid_metrics)
        t0 = time.time()
        rows.extend(mod.run())
        timings[name] = round(time.time() - t0, 2)
        if getattr(mod, "REUSES_SHARED_GRID", False) and grid_was_built:
            reused[name] = "shared_grid"
            if timings[name] < 0.05:
                # pure grid reader: its work was paid for under
                # shared_grid_wall_s, so a 0.0 here would misread as
                # "this figure is free" in the perf trajectory
                timings[name] = "reused"
        rows.append((f"_elapsed_{name}", timings[name], "seconds"))

    if args.smoke:
        # the three-layer crash demo rides the smoke path so it can't rot
        from examples.crash_recovery_demo import main as demo_main
        t0 = time.time()
        demo_main()
        timings["crash_recovery_demo"] = round(time.time() - t0, 2)
        rows.append(("_elapsed_crash_recovery_demo",
                     timings["crash_recovery_demo"], "seconds"))
    _shared.emit(rows)

    if args.out is None:
        return
    report = {
        "smoke": args.smoke,
        "budget": _shared.BUDGET,
        "bucket": _shared.bucket(),
        # measurement methodology marker: *_wall_s is the WARM re-run,
        # *_compile_s the cold-warm delta (benchmarks.compare refuses to
        # ratio reports measured under a different convention)
        "timing": "cold_warm_split",
        "total_wall_s": round(time.time() - t_start, 2),
        "compile_count": compile_count(),
        "figures_wall_s": timings,
        # figures whose wall time excludes a shared artifact they reuse
        # (the shared grid is attributed once, under shared_grid_wall_s)
        "figures_reused": reused,
        # telemetry of the shared {workload x scheme} one-program grid
        **{f"shared_{k}": v for k, v in _shared.grid_metrics.items()},
        # telemetry of the {scheme x switch-depth x crash} chain sweep
        **fig1_switch_depth.sweep_metrics,
        # telemetry of the {workload x scheme x crash-point} sweep
        **fig_recovery.sweep_metrics,
        # telemetry of the {tenant-count x scheme} shared-switch sweep
        **fig_tenants.sweep_metrics,
        # telemetry of the mixed {scheme x policy} QoS sweep
        **fig_qos.sweep_metrics,
        # telemetry of the {offered-load x scheme x policy} SLO sweep
        **fig_slo.sweep_metrics,
        # telemetry of the {scheme x leaves x placement x bp} fabric sweep
        **fig_fabric.sweep_metrics,
        # telemetry of the epoched {rate x strategy x crash} dynamic sweep
        **fig_dynamic.sweep_metrics,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
