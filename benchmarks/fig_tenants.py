"""Tenant sweep: shared-switch scale-out under multi-tenant contention.

Independent hosts (tenants) share one persistent switch — the paper's
data-center memory-pooling pitch.  Each tenant runs its own
``CORES_PER_TENANT``-core copy of the workload with a fixed per-tenant
persist budget, so offered load grows with the tenant count while the
PB slots, the PBC FIFO and the PM banks stay fixed: persist latency
degrades with contention and the per-tenant stats rows expose how
fairly the shared switch spreads that pain.

The whole sweep — every {tenant count x scheme}, plus a shared-hot-set
contention variant at the highest tenant count — is ONE ``simulate_cells``
call: the tenant count is a traced config scalar like every latency, so
the mixed-tenant sweep shares a single XLA program (the compile-count
guard in ``make ci`` pins this), and the flat paired-cell API runs only
the diagonal the figure reads (a config's tenant count must match its
trace's partition) instead of the full cross product.

Reported per (scheme, T):
  * mean persist latency (ns) over all tenants;
  * fairness: max/min ratio of per-tenant mean persist latencies
    (1.0 = perfectly fair);
  * per-tenant PBC queueing share via the stall/queue accumulators.
"""
from __future__ import annotations

import math

from repro.core import PCSConfig, Scheme, make_tenant_trace
from repro.core.engine import simulate_cells
from repro.core.engine.state import S_PBCQ_SUM, S_PERSIST_CNT

from benchmarks import _shared
from benchmarks.fig_recovery import SCHEMES

COUNTS = (1, 2, 4, 8)
SMOKE_COUNTS = (1, 2, 4)
WORKLOAD = "radiosity"
CORES_PER_TENANT = 2
SHARED_HOT_LINES = 18          # radiosity's whole hot set, contended

# telemetry of the tenant sweep for BENCH_engine.json (set by run())
sweep_metrics: dict = {}


def _fairness(r) -> float:
    """Max/min ratio of per-tenant mean persist latencies (NaN-safe)."""
    lats = [t.persist_lat_ns for t in r.tenant_results()
            if not math.isnan(t.persist_lat_ns)]
    if not lats or min(lats) <= 0:
        return float("nan")
    return max(lats) / min(lats)


def run() -> list:
    counts = SMOKE_COUNTS if _shared.SMOKE else COUNTS
    budget = max(_shared.BUDGET // 4, 100)      # per tenant
    traces = [make_tenant_trace(WORKLOAD, t, CORES_PER_TENANT,
                                persist_budget=budget)
              for t in counts]
    t_hot = counts[-1]
    hot_trace = make_tenant_trace(WORKLOAD, t_hot, CORES_PER_TENANT,
                                  persist_budget=budget,
                                  shared_lines=SHARED_HOT_LINES)
    # Flat paired cells: a config's tenant count only means something on
    # the trace with the matching partition, so the sweep pairs each
    # config with exactly that trace — one shared vmap axis, one program.
    cell_traces, configs, keys = [], [], []
    for key, scheme in SCHEMES:
        for i, t in enumerate(counts):
            cell_traces.append(traces[i])
            configs.append(PCSConfig(
                scheme=scheme, n_tenants=t,
                n_cores=t * CORES_PER_TENANT))
            keys.append((key, t, False))
        # shared-hot-set contention variant: all tenants fight over one
        # hot set instead of private address spaces (read forwarding +
        # coalescing now cross tenants; fairness typically degrades)
        cell_traces.append(hot_trace)
        configs.append(PCSConfig(
            scheme=scheme, n_tenants=t_hot,
            n_cores=t_hot * CORES_PER_TENANT))
        keys.append((key, t_hot, True))
    cells, m = _shared.timed_sweep(
        lambda: simulate_cells(cell_traces, configs,
                               bucket=_shared.bucket()))
    sweep_metrics.update(
        tenant_sweep_wall_s=m["wall_s"],
        tenant_sweep_compile_s=m["compile_s"],
        tenant_sweep_compiles=m["compiles"],
        tenant_sweep_cells=len(configs),
        tenant_sweep_macro_hit=m["macro_hit"],
        tenant_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    for (key, t_cfg, hot), r in zip(keys, cells):
        if math.isnan(r.persist_lat_ns):
            continue                    # empty cell: no persists to plot
        if hot:
            rows.append((f"tenants_hot_persist_{key}_T{t_cfg}",
                         round(r.persist_lat_ns, 1), "ns"))
            rows.append((f"tenants_hot_fair_{key}_T{t_cfg}",
                         round(_fairness(r), 3), "max_min_tenant_ratio"))
            continue
        rows.append((f"tenants_persist_{key}_T{t_cfg}",
                     round(r.persist_lat_ns, 1), "ns"))
        rows.append((f"tenants_fair_{key}_T{t_cfg}",
                     round(_fairness(r), 3), "max_min_tenant_ratio"))
        if r.tenant_stats is not None:
            q = r.tenant_stats[:, S_PBCQ_SUM]
            n = r.tenant_stats[:, S_PERSIST_CNT]
            worst = max(float(qi / ni) for qi, ni in zip(q, n)
                        if ni > 0)
            rows.append((f"tenants_pbcq_{key}_T{t_cfg}",
                         round(worst, 1), "worst_tenant_pbcq_ns"))
    return rows


def main() -> None:
    _shared.emit(run())


if __name__ == "__main__":
    main()
