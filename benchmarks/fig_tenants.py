"""Tenant sweep: shared-switch scale-out under multi-tenant contention.

Independent hosts (tenants) share one persistent switch — the paper's
data-center memory-pooling pitch.  Each tenant runs its own
``CORES_PER_TENANT``-core copy of the workload with a fixed per-tenant
persist budget, so offered load grows with the tenant count while the
PB slots, the PBC FIFO and the PM banks stay fixed: persist latency
degrades with contention and the per-tenant stats rows expose how
fairly the shared switch spreads that pain.

The whole sweep — every {tenant count x scheme}, plus a shared-hot-set
contention variant at the highest tenant count — is ONE ``simulate_grid``
call: the tenant count is a traced config scalar like every latency, so
the mixed-tenant grid shares a single XLA program (the compile-count
guard in ``make ci`` pins this).

Reported per (scheme, T):
  * mean persist latency (ns) over all tenants;
  * fairness: max/min ratio of per-tenant mean persist latencies
    (1.0 = perfectly fair);
  * per-tenant PBC queueing share via the stall/queue accumulators.
"""
from __future__ import annotations

import math
import time

from repro.core import PCSConfig, Scheme, make_tenant_trace, simulate_grid
from repro.core.engine import compile_count
from repro.core.engine.state import S_PBCQ_SUM, S_PERSIST_CNT

from benchmarks import _shared
from benchmarks.fig_recovery import SCHEMES

COUNTS = (1, 2, 4, 8)
SMOKE_COUNTS = (1, 2, 4)
WORKLOAD = "radiosity"
CORES_PER_TENANT = 2
SHARED_HOT_LINES = 18          # radiosity's whole hot set, contended

# telemetry of the tenant sweep for BENCH_engine.json (set by run())
sweep_metrics: dict = {}


def _fairness(r) -> float:
    """Max/min ratio of per-tenant mean persist latencies (NaN-safe)."""
    lats = [t.persist_lat_ns for t in r.tenant_results()
            if not math.isnan(t.persist_lat_ns)]
    if not lats or min(lats) <= 0:
        return float("nan")
    return max(lats) / min(lats)


def run() -> list:
    counts = SMOKE_COUNTS if _shared.SMOKE else COUNTS
    budget = max(_shared.BUDGET // 4, 100)      # per tenant
    traces = [make_tenant_trace(WORKLOAD, t, CORES_PER_TENANT,
                                persist_budget=budget)
              for t in counts]
    t_hot = counts[-1]
    traces.append(make_tenant_trace(WORKLOAD, t_hot, CORES_PER_TENANT,
                                    persist_budget=budget,
                                    shared_lines=SHARED_HOT_LINES))
    # The grid is a {trace x config} cross product; only the diagonal
    # cells (config tenant count == trace tenant structure) are read,
    # still one compiled program (same pattern as fig_recovery).
    configs, keys = [], []
    for key, scheme in SCHEMES:
        for t in counts:
            configs.append(PCSConfig(
                scheme=scheme, n_tenants=t,
                n_cores=t * CORES_PER_TENANT))
            keys.append((key, t))
    c0, t0 = compile_count(), time.time()
    cells = simulate_grid(traces, configs, bucket=_shared.bucket())
    sweep_metrics.update(
        tenant_sweep_wall_s=round(time.time() - t0, 3),
        tenant_sweep_compiles=compile_count() - c0,
        tenant_sweep_cells=len(traces) * len(configs),
    )
    rows = []
    for i, t_trace in enumerate(counts):
        for (key, t_cfg), r in zip(keys, cells[i]):
            if t_cfg != t_trace:        # off-diagonal: wrong partition
                continue
            if math.isnan(r.persist_lat_ns):
                continue                # empty cell: no persists to plot
            rows.append((f"tenants_persist_{key}_T{t_cfg}",
                         round(r.persist_lat_ns, 1), "ns"))
            rows.append((f"tenants_fair_{key}_T{t_cfg}",
                         round(_fairness(r), 3), "max_min_tenant_ratio"))
            if r.tenant_stats is not None:
                q = r.tenant_stats[:, S_PBCQ_SUM]
                n = r.tenant_stats[:, S_PERSIST_CNT]
                worst = max(float(qi / ni) for qi, ni in zip(q, n)
                            if ni > 0)
                rows.append((f"tenants_pbcq_{key}_T{t_cfg}",
                             round(worst, 1), "worst_tenant_pbcq_ns"))
    # shared-hot-set contention variant: all tenants fight over one hot
    # set instead of private address spaces (read forwarding + coalescing
    # now cross tenants; fairness typically degrades)
    for (key, t_cfg), r in zip(keys, cells[len(counts)]):
        if t_cfg != t_hot or math.isnan(r.persist_lat_ns):
            continue
        rows.append((f"tenants_hot_persist_{key}_T{t_cfg}",
                     round(r.persist_lat_ns, 1), "ns"))
        rows.append((f"tenants_hot_fair_{key}_T{t_cfg}",
                     round(_fairness(r), 3), "max_min_tenant_ratio"))
    return rows


def main() -> None:
    _shared.emit(run())


if __name__ == "__main__":
    main()
