"""QoS policy sweep: quota / victim / drain-scope policies vs fairness.

A *noisy* tenant (write-hot radiosity at full budget) shares one
persistent switch with three quiet tenants; without QoS the noisy
tenant's allocations and drain-downs monopolize the shared PB, skewing
per-tenant persist latency (the PR 3 fairness finding).  This figure
sweeps the declarative :class:`~repro.core.params.PBPolicy` surface over
both ack-at-switch schemes and reports the PR 3 fairness metrics per
policy:

  * mean persist latency and the max/min tenant-latency ratio;
  * the worst tenant's mean PBC queueing wait;
  * victim/recycle events (quota pressure made visible).

The whole {scheme x policy} sweep — four policies, default included —
is ONE ``simulate_grid`` call: every policy field lowers to a traced
scalar or per-tenant vector, so mixing policies costs no extra XLA
programs (the ``qos_sweep_compiles`` guard in ``make ci`` pins this).
"""
from __future__ import annotations

import math

from repro.core import (AllocPolicy, DrainPolicy, PBPolicy, PCSConfig,
                        Scheme, make_mixed_tenant_trace, simulate_grid)
from repro.core.engine.state import S_PBCQ_SUM, S_PERSIST_CNT

from benchmarks import _shared
from benchmarks.fig_tenants import _fairness

N_TENANTS = 4
CORES_PER_TENANT = 2
SCHEMES = (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF))

# The policy axis: default, even quotas, even quotas + weighted victim
# selection, and tenant-scoped drain-down on top (>= 3 non-default
# policies mixed with the default, per the ISSUE 4 acceptance grid).
POLICIES = (
    ("default", PBPolicy()),
    ("quota", PBPolicy(alloc=AllocPolicy(tenant_quota=(4, 4, 4, 4)))),
    ("quota_weighted", PBPolicy(alloc=AllocPolicy(
        victim="weighted", tenant_quota=(4, 4, 4, 4)))),
    ("tenant_drain", PBPolicy(
        drain=DrainPolicy(per_tenant=True),
        alloc=AllocPolicy(victim="weighted", tenant_quota=(4, 4, 4, 4)))),
)

# telemetry of the QoS sweep for BENCH_engine.json (set by run())
sweep_metrics: dict = {}


def _noisy_mix(noisy: str, quiet: str, name: str):
    budget = max(_shared.BUDGET // 4, 100)
    specs = [(noisy, budget)] + \
            [(quiet, max(budget // 4, 25))] * (N_TENANTS - 1)
    return make_mixed_tenant_trace(specs, CORES_PER_TENANT, name=name)


# two noisy-neighbour workload mixes — the sweep is a literal
# {workload x scheme x policy} grid in one compiled program
MIXES = (("radio", "radiosity", "radiosity"),
         ("ray", "radiosity", "raytrace"))


def run() -> list:
    traces = [_noisy_mix(noisy, quiet, f"qos_{mkey}")
              for mkey, noisy, quiet in MIXES]
    configs, keys = [], []
    for skey, scheme in SCHEMES:
        for pkey, pol in POLICIES:
            configs.append(PCSConfig(
                scheme=scheme, n_tenants=N_TENANTS,
                n_cores=N_TENANTS * CORES_PER_TENANT, policy=pol))
            keys.append((skey, pkey))
    cells, m = _shared.timed_sweep(
        lambda: simulate_grid(traces, configs, bucket=_shared.bucket()))
    sweep_metrics.update(
        qos_sweep_wall_s=m["wall_s"],
        qos_sweep_compile_s=m["compile_s"],
        qos_sweep_compiles=m["compiles"],
        qos_sweep_cells=len(traces) * len(configs),
        qos_sweep_macro_hit=m["macro_hit"],
        qos_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    for (mkey, _, _), row in zip(MIXES, cells):
        for (skey, pkey), r in zip(keys, row):
            if math.isnan(r.persist_lat_ns):
                continue
            rows.append((f"qos_persist_{mkey}_{skey}_{pkey}",
                         round(r.persist_lat_ns, 1), "ns"))
            rows.append((f"qos_fair_{mkey}_{skey}_{pkey}",
                         round(_fairness(r), 3), "max_min_tenant_ratio"))
            rows.append((f"qos_victims_{mkey}_{skey}_{pkey}",
                         r.victim_drains, "victim_recycle_events"))
            if r.tenant_stats is not None:
                q = r.tenant_stats[:, S_PBCQ_SUM]
                n = r.tenant_stats[:, S_PERSIST_CNT]
                worst = max(float(qi / ni)
                            for qi, ni in zip(q, n) if ni > 0)
                rows.append((f"qos_pbcq_{mkey}_{skey}_{pkey}",
                             round(worst, 1), "worst_tenant_pbcq_ns"))
    return rows


def main() -> None:
    _shared.emit(run())


if __name__ == "__main__":
    main()
