"""Kernel micro-bench: wall time of the jnp reference paths on CPU plus
interpret-mode correctness deltas (Pallas timing is only meaningful on
TPU; this records the oracle cost the kernels replace)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from benchmarks._shared import emit


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / reps


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    q = jnp.asarray(rng.standard_normal((1, 4, 1024, 128)), jnp.float32)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    rows.append(("kernel_attn_ref_b1h4s1024d128", round(_time(f, q, q, q), 1),
                 "us_per_call"))
    x = jnp.asarray(rng.standard_normal((1, 1024, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (1, 1024, 8)), jnp.float32)
    A = jnp.asarray(-np.ones(8), jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 1024, 128)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((1, 1024, 128)), jnp.float32)
    g = jax.jit(lambda *a: ref.ssd_scan_ref(*a))
    rows.append(("kernel_ssd_ref_s1024h8p64n128", round(_time(g, x, dt, A, B, C), 1),
                 "us_per_call"))
    req = jnp.asarray(rng.integers(0, 64, 4096), jnp.int32)
    tat = jnp.asarray(rng.integers(0, 64, 64), jnp.int32)
    st = jnp.asarray(rng.integers(0, 3, 64), jnp.int32)
    h = jax.jit(lambda *a: ref.tat_lookup_ref(*a))
    rows.append(("kernel_tat_ref_r4096n64", round(_time(h, req, tat, st), 1),
                 "us_per_call"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
