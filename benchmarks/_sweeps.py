"""Single source of truth for the benchmark sweep telemetry names.

Every one-program sweep records five keys into BENCH_engine.json —
``<sweep>_wall_s`` (warm run), ``<sweep>_compile_s`` (XLA compile
latency, recorded separately so a compile-cache hit can't mask a run
regression), ``<sweep>_compiles``, ``<sweep>_cells`` and
``<sweep>_macro_hit``.  ``check_compiles`` derives its GUARDED /
MACRO_KEYS tuples from this list, and the ``repro.analysis`` sweeps
pass cross-checks it against the ``sweep_metrics.update(...)`` sites
the figure scripts actually emit — adding a sweep without registering
it here (or retiring one without removing it) fails ``make lint``.

Keep this module a leaf: AST-parsed by the linter, imported by
check_compiles; no engine imports.
"""
from __future__ import annotations

from typing import Tuple

# sweep base names, one per one-XLA-program benchmark sweep
SWEEPS: Tuple[str, ...] = (
    "shared_grid",     # the {workload x scheme} grid (_shared.py)
    "chain_sweep",     # {scheme x switch-depth x crash} (fig1_switch_depth)
    "recovery_sweep",  # {workload x scheme x crash-point} (fig_recovery)
    "tenant_sweep",    # {tenant-count x scheme} (fig_tenants)
    "qos_sweep",       # mixed {scheme x policy} (fig_qos)
    "slo_sweep",       # {offered-load x scheme x policy} (fig_slo)
    "fabric_sweep",    # {scheme x leaves x placement x bp} (fig_fabric)
    "dynamic_sweep",   # {rate x strategy x crash} epoched (fig_dynamic)
)

# per-sweep telemetry key suffixes every sweep must emit
SUFFIXES: Tuple[str, ...] = ("wall_s", "compile_s", "compiles", "cells",
                             "macro_hit")

# macro abort-reason names, one per row of the engine's one-hot abort
# vector.  Duplicated from engine.macro.MACRO_ABORT_REASONS so this
# module stays a leaf (no engine import); tests/test_epoch_schedules.py
# pins the two tuples equal.
ABORT_REASONS: Tuple[str, ...] = ("window", "fabric", "deep",
                                  "epoch_boundary", "interleave", "guard")


def guarded() -> Tuple[str, ...]:
    """Keys whose value must be exactly 1 (one XLA program per sweep)."""
    return tuple(f"{s}_compiles" for s in SWEEPS)


def macro_keys() -> Tuple[str, ...]:
    """Keys holding each sweep's macro-step hit-rate fraction."""
    return tuple(f"{s}_macro_hit" for s in SWEEPS)


def abort_keys() -> Tuple[str, ...]:
    """Keys holding each sweep's macro abort-reason counter dict
    (``engine.last_macro_abort_reasons()``: reason -> aborted windows)."""
    return tuple(f"{s}_macro_aborts" for s in SWEEPS)
