"""Fig 7: PB_RF read-hit rate and write-coalescing rate per workload.
Paper: radiosity ~51% hit / ~50% coalesce; cholesky & volrend ~1%; FFT
coalescing 2.8%; others ~20%.

Cells come from the shared one-program {workload x scheme} grid
(`_shared.result` -> `simulate_grid`)."""
from __future__ import annotations

from repro.core import Scheme

from benchmarks._shared import emit, result, workloads


# consumes the cached one-program {workload x scheme} grid: wall
# time excludes the grid build whenever another figure paid for it
REUSES_SHARED_GRID = True


def run() -> list:
    rows = []
    for name in workloads():
        r = result(name, Scheme.PB_RF)
        rows.append((f"fig7a_hit_{name}", round(100 * r.read_hit_rate, 1),
                     "pct"))
        rows.append((f"fig7b_coalesce_{name}",
                     round(100 * r.coalesce_rate, 1), "pct"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
