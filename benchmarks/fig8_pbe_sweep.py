"""Fig 8: speedup vs PBE count for radiosity / cholesky / FFT.

The whole figure — three workloads x {NoPB baseline, PB/PB_RF at every
PBE count} — is ONE ``simulate_grid`` call: the PBE count enters as
traced tag/data latencies (CACTI trend) and a traced live-entry bound,
and the scheme id is traced too, so the mixed-scheme grid shares a
single compiled program.
"""
from __future__ import annotations

from repro.core import PCSConfig, Scheme, simulate_grid

from benchmarks import _shared
from benchmarks._shared import emit, trace

COUNTS = (8, 16, 32, 64, 128)
# smoke keeps max_pbe small: the RF drain policy does O(max_pbe^2) work
# per step, and the vmapped grid pays it for every cell
SMOKE_COUNTS = (8, 16, 32)
NAMES = ("radiosity", "cholesky", "fft")


def run() -> list:
    counts = SMOKE_COUNTS if _shared.SMOKE else COUNTS
    traces = [trace(n) for n in NAMES]
    configs = [PCSConfig(scheme=Scheme.NOPB)]
    keys = [("nopb", 16)]
    for key, scheme in (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF)):
        for n in counts:
            configs.append(PCSConfig(scheme=scheme, n_pbe=n))
            keys.append((key, n))
    cells = simulate_grid(traces, configs, bucket=_shared.bucket())
    rows = []
    for name, row in zip(NAMES, cells):
        nopb = row[0]
        for (key, n), r in zip(keys[1:], row[1:]):
            s = 100.0 * (nopb.runtime_ns / r.runtime_ns - 1.0)
            rows.append((f"fig8_{key}_{name}_pbe{n}", round(s, 1),
                         "speedup_%"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
