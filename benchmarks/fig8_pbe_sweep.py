"""Fig 8: speedup vs PBE count for radiosity / cholesky / FFT.

One vmap per (workload, scheme): the PBE count enters as traced tag/data
latencies (CACTI trend) and a traced live-entry bound.
"""
from __future__ import annotations

from repro.core import PCSConfig, Scheme, simulate, simulate_sweep

from benchmarks._shared import emit, trace

COUNTS = (8, 16, 32, 64, 128)
NAMES = ("radiosity", "cholesky", "fft")


def run() -> list:
    rows = []
    for name in NAMES:
        tr = trace(name)
        nopb = simulate(tr, PCSConfig(scheme=Scheme.NOPB))
        for key, scheme in (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF)):
            cfgs = [PCSConfig(scheme=scheme, n_pbe=n) for n in COUNTS]
            for n, r in zip(COUNTS, simulate_sweep(tr, cfgs)):
                s = 100.0 * (nopb.runtime_ns / r.runtime_ns - 1.0)
                rows.append((f"fig8_{key}_{name}_pbe{n}", round(s, 1),
                             "speedup_%"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
