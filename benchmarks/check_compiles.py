"""CI compile-count regression guard over BENCH_engine.json.

The engine's one-program property — a whole {trace x config x scheme x
crash-point x tenant-count x policy x switch-depth} grid lowering to a
single XLA compilation — is a load-bearing perf invariant (DESIGN.md
§3).  ``make ci`` runs this after ``bench-smoke``: if the shared grid,
the recovery sweep, the tenant sweep, the mixed-policy QoS sweep, the
offered-load SLO sweep, the fabric sweep, the epoched dynamic sweep or
the switch-chain depth sweep ever compiles more than once (e.g.
someone turns a traced scalar — the chain depth, a per-hop capacity or
a lowered PBPolicy field — back into a static), the build fails loudly
instead of the trajectory silently absorbing a multi-compile
regression.

The macro-stepping fast path (DESIGN.md "Macro-stepping & state
packing") is enabled in every benchmark sweep, so the counts above also
pin that the macro-enabled grid still lowers to ONE XLA program per
sweep — the guarded macro-step is part of the same scan body, never a
second program.  Each sweep additionally records its ``*_macro_hit``
(fraction of trace slots executed via committed macro-steps); this
guard requires the telemetry to be present and sane, so a regression
that silently disables macro-stepping (hit rate pinned at 0 would be
visible in review) or drops the telemetry fails CI.

    PYTHONPATH=src python -m benchmarks.check_compiles [report.json]
"""
from __future__ import annotations

import json
import sys

from benchmarks._sweeps import ABORT_REASONS, abort_keys, guarded, macro_keys

# all tuples derive from the one sweep-name list in benchmarks._sweeps;
# repro.analysis cross-checks that list against the keys the figure
# scripts actually emit
GUARDED = guarded()

# macro-stepping telemetry: every sweep must record its hit rate and
# its abort-reason counters (why candidate windows fell back to the
# scalar path: window / fabric / deep / epoch_boundary / interleave /
# guard); the counter dict must carry EXACTLY that reason set, so a new
# abort reason (or a dropped one) can't ship without its telemetry
MACRO_KEYS = macro_keys()
ABORT_KEYS = abort_keys()


def check(report: dict) -> list:
    problems = []
    for key in GUARDED:
        v = report.get(key)
        if v is None:
            problems.append(f"{key}: missing from the report (sweep "
                            "didn't run or telemetry was dropped)")
        elif v != 1:
            problems.append(f"{key} = {v}: grid no longer lowers to one "
                            "XLA program (macro-stepping included, the "
                            "sweep must stay a single compilation)")
    for key in MACRO_KEYS:
        v = report.get(key)
        if v is None:
            problems.append(f"{key}: missing from the report (macro "
                            "hit-rate telemetry was dropped)")
        elif not isinstance(v, (int, float)) or not 0.0 <= v <= 1.0:
            problems.append(f"{key} = {v!r}: macro hit rate must be a "
                            "fraction in [0, 1]")
    for key in ABORT_KEYS:
        v = report.get(key)
        if v is None:
            problems.append(f"{key}: missing from the report (macro "
                            "abort-reason telemetry was dropped)")
        elif (not isinstance(v, dict) or set(v) != set(ABORT_REASONS)
              or any(not isinstance(n, int) or n < 0
                     for n in v.values())):
            problems.append(f"{key} = {v!r}: abort counters must be a "
                            "{reason: count >= 0} dict over exactly "
                            f"{sorted(ABORT_REASONS)}")
    return problems


def main(argv=None) -> int:
    path = (argv or sys.argv[1:] or ["BENCH_engine.json"])[0]
    try:
        with open(path) as f:
            report = json.load(f)
    except OSError as e:
        print(f"check_compiles: cannot read {path}: {e}", file=sys.stderr)
        return 2
    problems = check(report)
    if problems:
        for p in problems:
            print(f"check_compiles: FAIL {p}", file=sys.stderr)
        return 1
    counts = {k: report[k] for k in GUARDED}
    hits = {k: report[k] for k in MACRO_KEYS}
    print(f"check_compiles: OK {counts}")
    print(f"check_compiles: macro hit rates {hits}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
