"""Fig 1: persist latency vs number of CXL switches to PM.

Paper claim: persist latency grows steeply with chain depth for a
volatile switch (~2.5x at one switch vs local PM) and is largely flat
when persists complete at the first persistent switch.

Latency (not throughput) measurement: a low-intensity FFT-like
persist/read mix (1:1, one core, 2 us of compute between operations) so
device queueing does not mask the path composition — the paper's Fig 1
is likewise a latency figure, normalized to local PM.

The whole depth sweep — NoPB at every depth plus PB at every depth with
a switch — is one mixed-scheme ``simulate_grid`` call: switch depth
enters through the traced one-way latencies and the scheme is a traced
scalar, so the figure costs a single XLA compilation.
"""
from __future__ import annotations

import numpy as np

from repro.core import Op, PCSConfig, Scheme, Trace, simulate_grid

from benchmarks import _shared
from benchmarks._shared import emit


def _probe_trace(n_ops: int = 2000, gap: float = 2000.0) -> Trace:
    ops, addrs = [], []
    for i in range(n_ops):
        ops.append(int(Op.PERSIST))
        addrs.append(i)                   # FFT: each line persisted once/stage
        ops.append(int(Op.PM_READ))
        addrs.append((1 << 20) + i)       # butterfly partner read
    return Trace(ops=np.array([ops], np.int32),
                 addrs=np.array([addrs], np.int32),
                 gaps=np.full((1, len(ops)), gap, np.float32),
                 lengths=np.array([len(ops)], np.int32), name="fig1_probe")


def run(depths=(0, 1, 2, 3)) -> list:
    tr = _probe_trace(n_ops=200 if _shared.SMOKE else 2000)
    labels, configs = [], []
    for n_sw in depths:
        labels.append(("nopb", n_sw))
        configs.append(PCSConfig(scheme=Scheme.NOPB, n_switches=n_sw))
        if n_sw > 0:
            labels.append(("pb", n_sw))
            configs.append(PCSConfig(scheme=Scheme.PB, n_switches=n_sw))
    cells = simulate_grid([tr], configs, bucket=_shared.bucket())[0]
    base = next(r.persist_lat_ns for (k, n), r in zip(labels, cells)
                if k == "nopb" and n == depths[0])
    rows = []
    for (key, n_sw), r in zip(labels, cells):
        rows.append((f"fig1_{key}_n{n_sw}", round(r.persist_lat_ns, 1),
                     f"norm={r.persist_lat_ns / base:.2f}x"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
