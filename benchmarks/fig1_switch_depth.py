"""Fig 1 (headline): persist latency and recovery vs CXL switch depth.

Paper claim: persist latency grows steeply with chain depth for a
volatile switch (~2.5x at one switch vs local PM) and is largely flat
when persists complete at the first persistent switch — a win that
*grows* with depth now that every switch in the chain carries its own
PB (pooling topologies): the ack point stays at hop 1 no matter how
deep the pool fabric gets.

Latency (not throughput) measurement: a low-intensity FFT-like
persist/read mix (1:1, one core, 2 us of compute between operations) so
device queueing does not mask the path composition — the paper's Fig 1
is likewise a latency figure, normalized to local PM.

Series shapes: **NoPB appears at every depth (0 included — direct
attach)**; the PB schemes only at depth >= 1, since the persistent
buffer lives in the first switch.  The whole sweep — the latency grid
plus a crashed replica of every PB cell for the per-hop recovered-entry
attribution — is ONE mixed-scheme ``simulate_grid`` call: switch depth,
per-hop capacities and the crash instant are all traced, so the figure
costs a single XLA compilation (``chain_sweep_compiles`` is guarded by
``benchmarks/check_compiles.py``).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import Op, PCSConfig, Scheme, Trace, simulate_grid
from benchmarks import _shared
from benchmarks._shared import emit

DEPTHS = (0, 1, 2, 3, 4)
PB_SCHEMES = (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF))

# telemetry of the one-program depth sweep for BENCH_engine.json
sweep_metrics: dict = {}


def _probe_trace(n_ops: int = 2000, gap: float = 2000.0) -> Trace:
    ops, addrs = [], []
    for i in range(n_ops):
        ops.append(int(Op.PERSIST))
        addrs.append(i)                   # FFT: each line persisted once/stage
        ops.append(int(Op.PM_READ))
        addrs.append((1 << 20) + i)       # butterfly partner read
    return Trace(ops=np.array([ops], np.int32),
                 addrs=np.array([addrs], np.int32),
                 gaps=np.full((1, len(ops)), gap, np.float32),
                 lengths=np.array([len(ops)], np.int32), name="fig1_probe")


def plan(depths=DEPTHS):
    """(label, config) rows of the depth sweep: NoPB at EVERY depth,
    PB schemes only where a switch exists to host the buffer, plus a
    mid-run-crash replica of each PB cell for the per-hop recovered-
    entry attribution.  The crash anchor is the probe's nominal op
    span (gap-dominated, so it needs no prior simulation — the sweep
    stays one program)."""
    labels, configs = [], []
    for n_sw in depths:
        labels.append(("nopb", n_sw, False))
        configs.append(PCSConfig(scheme=Scheme.NOPB, n_switches=n_sw))
        if n_sw < 1:
            continue                      # no switch, nowhere for a PB
        for key, scheme in PB_SCHEMES:
            labels.append((key, n_sw, False))
            configs.append(PCSConfig(scheme=scheme, n_switches=n_sw))
    return labels, configs


def run(depths=None) -> list:
    # smoke caps the chain at depth 3: the deep-hop row count is a
    # static shape, and the depth-4 program alone dominates the smoke
    # lane's compile budget (full runs sweep the headline 1..4)
    if depths is None:
        depths = DEPTHS[:-1] if _shared.SMOKE else DEPTHS
    n_ops = 200 if _shared.SMOKE else 2000
    gap = 2000.0
    tr = _probe_trace(n_ops=n_ops, gap=gap)
    labels, configs = plan(depths)
    # crashed replicas: power loss mid-run (half the nominal op span)
    crash_at = 0.5 * (2 * n_ops) * gap
    for key, scheme in PB_SCHEMES:
        for n_sw in depths:
            if n_sw < 1:
                continue
            labels.append((key, n_sw, True))
            configs.append(PCSConfig(scheme=scheme, n_switches=n_sw)
                           .with_crash(crash_at))
    cells, m = _shared.timed_sweep(
        lambda: simulate_grid([tr], configs, bucket=_shared.bucket()))
    cells = cells[0]
    sweep_metrics.update(
        chain_sweep_wall_s=m["wall_s"],
        chain_sweep_compile_s=m["compile_s"],
        chain_sweep_compiles=m["compiles"],
        chain_sweep_cells=len(configs),
        chain_sweep_macro_hit=m["macro_hit"],
        chain_sweep_macro_aborts=m["macro_aborts"],
    )
    base = next(r.persist_lat_ns for (k, n, c), r in zip(labels, cells)
                if k == "nopb" and n == min(depths) and not c)
    rows = []
    for (key, n_sw, crashed), r in zip(labels, cells):
        if not crashed:
            rows.append((f"fig1_{key}_n{n_sw}",
                         round(r.persist_lat_ns, 1),
                         f"norm={r.persist_lat_ns / base:.2f}x"))
            # per-hop mean forward latency (chain telemetry); hops with
            # zero traffic have NaN means — skipped, not plotted as 0
            for h in r.hop_results():
                if math.isnan(h["fwd_lat_ns"]):
                    continue
                rows.append((f"fig1_fwd_{key}_n{n_sw}_h{h['hop']}",
                             round(h["fwd_lat_ns"], 1),
                             f"commits={h['commits']}"))
        else:
            # recovered-entry attribution: which hop of the chain holds
            # the surviving entries a mid-run crash leaves behind
            for h in r.hop_results():
                rows.append((f"fig1_recov_{key}_n{n_sw}_h{h['hop']}",
                             h["recovered"], "surviving_pbes"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
