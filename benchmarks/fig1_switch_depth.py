"""Fig 1: persist latency vs number of CXL switches to PM.

Paper claim: persist latency grows steeply with chain depth for a
volatile switch (~2.5x at one switch vs local PM) and is largely flat
when persists complete at the first persistent switch.

Latency (not throughput) measurement: a low-intensity FFT-like
persist/read mix (1:1, one core, 2 us of compute between operations) so
device queueing does not mask the path composition — the paper's Fig 1
is likewise a latency figure, normalized to local PM.
"""
from __future__ import annotations

import numpy as np

from repro.core import Op, PCSConfig, Scheme, Trace, simulate

from benchmarks._shared import emit


def _probe_trace(n_ops: int = 2000, gap: float = 2000.0) -> Trace:
    ops, addrs = [], []
    for i in range(n_ops):
        ops.append(int(Op.PERSIST))
        addrs.append(i)                   # FFT: each line persisted once/stage
        ops.append(int(Op.PM_READ))
        addrs.append((1 << 20) + i)       # butterfly partner read
    return Trace(ops=np.array([ops], np.int32),
                 addrs=np.array([addrs], np.int32),
                 gaps=np.full((1, len(ops)), gap, np.float32),
                 lengths=np.array([len(ops)], np.int32), name="fig1_probe")


def run(depths=(0, 1, 2, 3)) -> list:
    tr = _probe_trace()
    rows = []
    base = None
    for n_sw in depths:
        nopb = simulate(tr, PCSConfig(scheme=Scheme.NOPB, n_switches=n_sw))
        if base is None:
            base = nopb.persist_lat_ns
        rows.append((f"fig1_nopb_n{n_sw}", round(nopb.persist_lat_ns, 1),
                     f"norm={nopb.persist_lat_ns / base:.2f}x"))
        if n_sw > 0:
            pb = simulate(tr, PCSConfig(scheme=Scheme.PB, n_switches=n_sw))
            rows.append((f"fig1_pb_n{n_sw}", round(pb.persist_lat_ns, 1),
                         f"norm={pb.persist_lat_ns / base:.2f}x"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
