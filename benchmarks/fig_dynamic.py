"""Dynamic re-provisioning figure: epoched schedules under diurnal load.

Three provisioning strategies for the same 2-leaf PB_RF pool serving
four tenants whose offered load oscillates (the diurnal arrival process
from the SLO work): a **static** baseline (fixed quotas, fixed
placement), a **scheduled quota step** (tenant 0's share grows at the
mid-run shift while the cold tenants shrink), and a **mid-run
migration** (the tenant->leaf placement map flips at the same instant,
moving every tenant onto the other leaf).  Epoch boundaries, per-epoch
quota rows and per-epoch placement rows are all traced operands
(DESIGN.md §7), so the whole {arrival-rate x strategy x crash} matrix
is ONE ``simulate_grid`` call — ``dynamic_sweep_compiles`` is guarded
by ``benchmarks/check_compiles.py``.

Rows: P50/P95/P99 persist tails per {rate x strategy} (does the quota
step / migration buy tail latency under the load swing?), plus per-leaf
recovered-entry attribution on the crashed replicas — the migration
column's crash lands *after* the placement flip, so its surviving
entries recover split across BOTH leaves (drain-at-issue contract:
entries persist where they were issued), which is the observable
difference vs the static column.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import (AllocPolicy, DiurnalArrivals, FabricTopology,
                        PBPolicy, PCSConfig, Schedule, Scheme,
                        leaf_placement, make_offered_load_trace,
                        simulate_grid)

from benchmarks import _shared

WORKLOAD = "raytrace"
N_TENANTS = 4
N_CORES = 4                        # one core per tenant
LEAF_PBE = (4, 4)
SPINE_PBE = 4

# offered load axis, Mops/s per core (time-average; the diurnal process
# swings around it)
RATES_FULL = (0.5, 2.0, 8.0)
RATES_SMOKE = (0.5, 8.0)

# telemetry of the {rate x strategy x crash} dynamic sweep
sweep_metrics: dict = {}


def _configs(bound_ns: float, crash_ns: float):
    """(label, config) rows: three strategies x {live, crashed}."""
    place0 = leaf_placement(N_TENANTS, 2, "packed")
    place1 = tuple(1 - p for p in place0)          # hot-leaf flip
    quota0 = (2, 2, 2, 2)
    quota1 = (4, 2, 1, 1)          # tenant 0 heats up at the shift
    fab_static = FabricTopology(2, LEAF_PBE, SPINE_PBE, place0)
    fab_migrate = FabricTopology(
        2, LEAF_PBE, SPINE_PBE, Schedule((bound_ns,), (place0, place1)))
    strategies = (
        ("static",
         PBPolicy(alloc=AllocPolicy(tenant_quota=quota0)), fab_static),
        ("quota_sched",
         PBPolicy(alloc=AllocPolicy(
             tenant_quota=Schedule((bound_ns,), (quota0, quota1)))),
         fab_static),
        ("migrate",
         PBPolicy(alloc=AllocPolicy(tenant_quota=quota0)), fab_migrate),
    )
    labels, configs = [], []
    for key, pol, fab in strategies:
        for crashed in (False, True):
            labels.append((key, crashed))
            cfg = PCSConfig(scheme=Scheme.PB_RF, n_cores=N_CORES,
                            n_tenants=N_TENANTS, policy=pol, fabric=fab)
            configs.append(cfg.with_crash(crash_ns) if crashed else cfg)
    return labels, configs


def run() -> list:
    rates = RATES_SMOKE if _shared.SMOKE else RATES_FULL
    budget = max(_shared.BUDGET // 4, 150)
    traces = [make_offered_load_trace(
                  WORKLOAD, DiurnalArrivals(r), n_cores=N_CORES,
                  persist_budget=budget)
              for r in rates]
    # the schedule boundary sits at the midpoint of the longest trace's
    # nominal op span (the diurnal shift) and the crash replicas die at
    # 3/4 — past the flip, so migration recovery shows both leaves.
    # Both instants are traced operands: they never split the program.
    span = max(float(np.max(tr.gaps.sum(axis=1))) for tr in traces)
    labels, configs = _configs(bound_ns=0.5 * span, crash_ns=0.75 * span)
    cells, m = _shared.timed_sweep(
        lambda: simulate_grid(traces, configs, bucket=_shared.bucket()))
    sweep_metrics.update(
        dynamic_sweep_wall_s=m["wall_s"],
        dynamic_sweep_compile_s=m["compile_s"],
        dynamic_sweep_compiles=m["compiles"],
        dynamic_sweep_cells=len(traces) * len(configs),
        dynamic_sweep_macro_hit=m["macro_hit"],
        dynamic_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    for rate, row in zip(rates, cells):
        for (key, crashed), r in zip(labels, row):
            tag = f"{key}_{rate:g}"
            if not crashed:
                if math.isnan(r.persist_lat_p50):
                    continue        # zero-traffic cell: no percentiles
                rows.append((f"dyn_p50_{tag}",
                             round(r.persist_lat_p50, 1), "ns"))
                rows.append((f"dyn_p95_{tag}",
                             round(r.persist_lat_p95, 1), "ns"))
                rows.append((f"dyn_p99_{tag}",
                             round(r.persist_lat_p99, 1), "ns"))
            elif r.leaf_recovery is not None:
                # issue-time leaf attribution of the crash survivors
                for i, n in enumerate(r.leaf_recovery):
                    rows.append((f"dyn_recov_{tag}_leaf{i}", int(n),
                                 "surviving_pbes"))
    return rows


def main() -> None:
    _shared.emit(run())


if __name__ == "__main__":
    main()
