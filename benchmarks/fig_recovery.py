"""Recovery figure: durability and recovery cost vs crash time.

For NoPB / PB / PB_RF, crash the timed engine at fractions of the
workload's NoPB runtime and record (a) the persisted fraction — how much
of the issued work survives crash + recovery (Section V-D4) — and
(b) the modeled recovery latency of the drain-all pass over the
surviving Dirty/Drain PBEs.  The whole sweep — every workload x scheme x
crash point, plus a multi-tenant group — is ONE ``simulate_cells`` call:
the crash instant is a traced config scalar like every latency, and the
sweep was never a cross product (each crash group anchors on exactly one
trace), so the flat paired-cell API runs the diagonal the figure reads
instead of paying for every off-anchor cell.

The multi-tenant group adds the per-tenant recovery attribution
(ROADMAP crash/recovery fairness): for a T=2 shared switch, each
tenant's durable fraction and its share of the surviving re-drained
PBEs (``SimResult.tenant_results()`` / ``tenant_recovery``) — recovery
cost was previously reported only globally.

The ack-at-switch schemes dominate the volatile baseline here: at any
crash instant more persists have completed (acks come back from the
first switch), and all of them are durable.
"""
from __future__ import annotations

import math

from repro.core import PCSConfig, Scheme, make_tenant_trace, simulate_grid
from repro.core.engine import simulate_cells

from benchmarks import _shared
from benchmarks._shared import emit, trace

# consumes the cached one-program {workload x scheme} grid: wall
# time excludes the grid build whenever another figure paid for it
REUSES_SHARED_GRID = True


FRACS = (0.25, 0.5, 0.75)
NAMES = ("radiosity", "cholesky", "fft")
# smoke keeps one workload: the config axis carries one crash-anchor
# group per workload, so cells grow quadratically with the name count
SMOKE_NAMES = ("radiosity",)
SCHEMES = (("nopb", Scheme.NOPB), ("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF))

# telemetry of the recovery sweep for BENCH_engine.json (set by run())
sweep_metrics: dict = {}


TENANT_WORKLOAD = "radiosity"
TENANTS = 2
TENANT_CORES = 2

# switch-chain group: per-hop recovered-entry attribution at this depth
CHAIN_DEPTH = 2


def run() -> list:
    names = SMOKE_NAMES if _shared.SMOKE else NAMES
    # Crash instants anchor on EACH workload's own NoPB (cached)
    # runtime.  Each config pairs with exactly one trace, so the sweep
    # is a flat (trace, config) cell list — no off-anchor cells — and
    # still one compiled program (simulate_cells vmaps one shared axis).
    ends = {n: _shared.result(n, Scheme.NOPB).runtime_ns for n in names}
    cell_traces, configs, keys = [], [], []
    for name in names:
        for key, scheme in SCHEMES:
            for f in FRACS:
                cell_traces.append(trace(name))
                configs.append(
                    PCSConfig(scheme=scheme).with_crash(f * ends[name]))
                keys.append((name, key, f))
    # Switch-chain group (pooling topologies): the first workload under
    # a depth-CHAIN_DEPTH chain, crashed at the same fractions — the
    # per-hop recovered-entry attribution of the union drain-all.
    # Depth is traced, so the group rides the same one-program sweep.
    for key, scheme in SCHEMES[1:]:        # pb, pb_rf
        for f in FRACS:
            cell_traces.append(trace(names[0]))
            configs.append(PCSConfig(
                scheme=scheme,
                n_switches=CHAIN_DEPTH).with_crash(f * ends[names[0]]))
            keys.append(("chain", key, f))
    # Multi-tenant group (per-tenant recovery attribution): a T=2
    # shared-switch trace crashed at the same fractions of ITS OWN NoPB
    # runtime (anchored outside the counted sweep so the sweep stays one
    # compiled program), for the ack-at-switch schemes.
    t_budget = max(_shared.BUDGET // 4, 100)
    t_trace = make_tenant_trace(TENANT_WORKLOAD, TENANTS, TENANT_CORES,
                                persist_budget=t_budget)
    t_end = simulate_grid(
        [t_trace], [PCSConfig(scheme=Scheme.NOPB, n_tenants=TENANTS,
                              n_cores=TENANTS * TENANT_CORES)],
        bucket=_shared.bucket())[0][0].runtime_ns
    for key, scheme in SCHEMES[1:]:        # pb, pb_rf
        for f in FRACS:
            cell_traces.append(t_trace)
            configs.append(PCSConfig(
                scheme=scheme, n_tenants=TENANTS,
                n_cores=TENANTS * TENANT_CORES).with_crash(f * t_end))
            keys.append(("tenants", key, f))
    cells, m = _shared.timed_sweep(
        lambda: simulate_cells(cell_traces, configs,
                               bucket=_shared.bucket()))
    sweep_metrics.update(
        recovery_sweep_wall_s=m["wall_s"],
        recovery_sweep_compile_s=m["compile_s"],
        recovery_sweep_compiles=m["compiles"],
        recovery_sweep_cells=len(configs),
        recovery_sweep_macro_hit=m["macro_hit"],
        recovery_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    for (anchor, key, f), r in zip(keys, cells):
        if anchor not in names:
            continue
        name = anchor
        scheme = dict(SCHEMES)[key]
        total = _shared.result(name, scheme).persists
        frac = r.durable_persists / max(total, 1)
        rows.append((f"recovery_{key}_{name}_f{int(100 * f)}",
                     round(frac, 4), "durable_fraction_of_run"))
        rows.append((f"recovery_lat_{key}_{name}_f{int(100 * f)}",
                     round(r.recovery_ns, 1), "recovery_ns"))
    # per-hop recovery attribution of the chain group (anchored on the
    # first workload's trace); hops with zero traffic have NaN mean
    # forward latency — skipped, never emitted as a 0.0 ns hop
    for (anchor, key, f), r in zip(keys, cells):
        if anchor != "chain":
            continue
        for h in r.hop_results():
            rows.append((
                f"recovery_chain_{key}_d{CHAIN_DEPTH}"
                f"_f{int(100 * f)}_h{h['hop']}",
                h["recovered"], "surviving_pbes"))
            if not math.isnan(h["fwd_lat_ns"]):
                rows.append((
                    f"recovery_chain_fwd_{key}_d{CHAIN_DEPTH}"
                    f"_f{int(100 * f)}_h{h['hop']}",
                    round(h["fwd_lat_ns"], 1), "mean_fwd_ns"))
    # per-tenant recovery attribution (the multi-tenant cells)
    for (anchor, key, f), r in zip(keys, cells):
        if anchor != "tenants":
            continue
        for t, tr_t in enumerate(r.tenant_results()):
            # durable fraction of the tenant's whole offered run (same
            # convention as the global rows: budget is per tenant)
            rows.append((
                f"recovery_tenant_{key}_T{TENANTS}_f{int(100 * f)}_t{t}",
                round(tr_t.durable_persists / max(t_budget, 1), 4),
                "tenant_durable_fraction_of_run"))
            rows.append((
                f"recovery_tenant_surv_{key}_T{TENANTS}_f{int(100 * f)}_t{t}",
                tr_t.recovery_entries, "tenant_surviving_pbes"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
