"""Fabric figure: fan-out topologies, tenant placement, backpressure.

Tree-structured switch pools (FabricTopology): N leaf switches — each
the hop-1 ack point for its own tenants — fan into one shared spine in
front of the PM banks.  The sweep holds the *total* leaf PBE capacity
constant and varies how it is partitioned (1, 2, 4, 8 leaves), how the
tenants are placed onto the leaves (packed blocks vs round-robin
spread) and whether the spine's backpressure watermark defers leaf
drain-downs (``bp_high``), plus a mid-run-crash replica of every cell
for the per-leaf recovered-entry attribution (``SimResult.leaf_recovery``).

The whole {scheme x leaves x placement x backpressure x crash} matrix
is ONE mixed-topology ``simulate_grid`` call: ``n_leaves``, the
placement map, the per-leaf slot partition, ``bp_high`` and the crash
instant are all traced operands, so the figure costs a single XLA
compilation (``fabric_sweep_compiles`` is guarded by
``benchmarks/check_compiles.py``).  The 1-leaf column doubles as the
chain anchor: it is bit-identical to the linear 2-hop chain
(tests/test_crash_differential.py pins this).
"""
from __future__ import annotations

import numpy as np

from repro.core import (FabricTopology, Op, PCSConfig, Scheme, Trace,
                        leaf_placement, simulate_grid)

from benchmarks import _shared
from benchmarks._shared import emit

N_TENANTS = 8                      # one core per tenant
LEAVES = (1, 2, 4, 8)
TOTAL_LEAF_PBE = 16                # partitioned across the leaves
SPINE_PBE = 8
BP_HIGH = float(SPINE_PBE // 2)    # finite watermark column
PB_SCHEMES = (("pb", Scheme.PB), ("pb_rf", Scheme.PB_RF))
PLACEMENTS = ("packed", "spread")

# telemetry of the one-program fabric sweep for BENCH_engine.json
sweep_metrics: dict = {}


def _probe_trace(n_ops: int, gap: float) -> Trace:
    """Persist-heavy per-tenant streams over disjoint address blocks
    (tenant isolation — the regime a leaf partition is built for), hot
    enough that drain-downs and the spine fan-in actually engage."""
    C, L = N_TENANTS, 2 * n_ops
    ops = np.zeros((C, L), np.int32)
    addrs = np.zeros((C, L), np.int32)
    for c in range(C):
        base = c << 16                     # disjoint per-tenant block
        for i in range(n_ops):
            ops[c, 2 * i] = int(Op.PERSIST)
            addrs[c, 2 * i] = base + (i % 64)   # hot set: coalescing
            ops[c, 2 * i + 1] = int(Op.PM_READ)
            addrs[c, 2 * i + 1] = base + (1 << 10) + i
    return Trace(ops=ops, addrs=addrs,
                 gaps=np.full((C, L), gap, np.float32),
                 lengths=np.full((C,), L, np.int32), name="fab_probe")


def _fabric(n_leaves: int, mode: str,
            bp_high=None) -> FabricTopology:
    per = TOTAL_LEAF_PBE // n_leaves
    return FabricTopology(n_leaves, (per,) * n_leaves, SPINE_PBE,
                          leaf_placement(N_TENANTS, n_leaves, mode),
                          bp_high=bp_high)


def plan():
    """(label, config) rows: {scheme x leaf-count x placement x
    backpressure}, constant total leaf capacity.  At 1 leaf the spread
    placement and the watermark are degenerate (identical cell /
    rejected by validation), so only the packed/no-backpressure column
    exists there — the chain anchor."""
    labels, configs = [], []
    for key, scheme in PB_SCHEMES:
        for nl in LEAVES:
            for mode in PLACEMENTS:
                if nl == 1 and mode == "spread":
                    continue
                for bp in ((None, BP_HIGH) if nl >= 2 else (None,)):
                    labels.append((key, nl, mode, bp, False))
                    configs.append(PCSConfig(
                        scheme=scheme, n_cores=N_TENANTS,
                        n_tenants=N_TENANTS,
                        fabric=_fabric(nl, mode, bp)))
    return labels, configs


def run() -> list:
    n_ops = 150 if _shared.SMOKE else 1500
    gap = 500.0
    tr = _probe_trace(n_ops=n_ops, gap=gap)
    labels, configs = plan()
    # crashed replicas: power loss mid-run (half the nominal op span),
    # a traced scalar — the replicas ride in the same program
    crash_at = 0.5 * (2 * n_ops) * gap
    for lab, cfg in list(zip(labels, configs)):
        labels.append(lab[:-1] + (True,))
        configs.append(cfg.with_crash(crash_at))
    cells, m = _shared.timed_sweep(
        lambda: simulate_grid([tr], configs, bucket=_shared.bucket()))
    cells = cells[0]
    sweep_metrics.update(
        fabric_sweep_wall_s=m["wall_s"],
        fabric_sweep_compile_s=m["compile_s"],
        fabric_sweep_compiles=m["compiles"],
        fabric_sweep_cells=len(configs),
        fabric_sweep_macro_hit=m["macro_hit"],
        fabric_sweep_macro_aborts=m["macro_aborts"],
    )
    rows = []
    for (key, nl, mode, bp, crashed), r in zip(labels, cells):
        tag = f"{key}_l{nl}_{mode}" + ("_bp" if bp is not None else "")
        if not crashed:
            rows.append((f"fab_{tag}", round(r.persist_lat_ns, 1),
                         f"p99={r.persist_lat_pct(0.99):.0f}ns"))
            rows.append((f"fab_runtime_{tag}", round(r.runtime_ns, 0),
                         "ns"))
        elif r.leaf_recovery is not None:
            # per-leaf recovered-entry attribution: which leaf held the
            # surviving entries the crash left behind (placement skew)
            for i, n in enumerate(r.leaf_recovery):
                rows.append((f"fab_recov_{tag}_leaf{i}", int(n),
                             "surviving_pbes"))
            rows.append((f"fab_recov_{tag}_spine",
                         r.hop_results()[1]["recovered"],
                         "surviving_pbes"))
        else:
            # 1-leaf chain anchor: per-hop attribution only
            for h in r.hop_results():
                rows.append((f"fab_recov_{tag}_h{h['hop']}",
                             h["recovered"], "surviving_pbes"))
    return rows


def main() -> None:
    emit(run())


if __name__ == "__main__":
    main()
