"""Shared simulation cache for the per-figure benchmarks.

Every figure consumes the same (workload x scheme) grid; this module
runs each cell once per process and caches the SimResult.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, Tuple

from repro.core import PCSConfig, Scheme, WORKLOADS, make_trace, simulate

# full paper budget by default; BENCH_QUICK=1 runs a reduced grid fast
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
BUDGET = 8_000 if QUICK else 100_000

_traces: Dict[str, object] = {}
_results: Dict[Tuple[str, Scheme, int], object] = {}


def trace(name: str):
    if name not in _traces:
        _traces[name] = make_trace(name, persist_budget=BUDGET)
    return _traces[name]


def result(name: str, scheme: Scheme, n_pbe: int = 16):
    key = (name, scheme, n_pbe)
    if key not in _results:
        _results[key] = simulate(trace(name),
                                 PCSConfig(scheme=scheme, n_pbe=n_pbe))
    return _results[key]


def workloads():
    return list(WORKLOADS)


def emit(rows, header=("name", "value", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
