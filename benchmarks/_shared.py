"""Shared simulation cache for the per-figure benchmarks.

Every figure consumes the same (workload x scheme) grid.  Since the
engine traces the scheme id, the whole grid — all seven workloads under
NoPB/PB/PB_RF — runs as ONE compiled program via ``simulate_grid``; this
module runs it once per process, caches the per-cell results, and
records the grid wall time / compile count for BENCH_engine.json.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

from repro.core import PCSConfig, Scheme, WORKLOADS, make_trace
from repro.core.engine import (compile_count, last_macro_abort_reasons,
                               last_macro_hit_rate, simulate_grid)

# full paper budget by default; BENCH_QUICK=1 runs a reduced grid fast
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
BUDGET = 8_000 if QUICK else 100_000

SCHEMES = (Scheme.NOPB, Scheme.PB, Scheme.PB_RF)

# smoke mode: tiny traces, small shape buckets, sub-minute total runtime
SMOKE = False
_SMOKE_BUDGET = 600
_SMOKE_BUCKET = 2048
_SMOKE_TRACE_KW = {"fft": {"m": 9}}

_traces: Dict[str, object] = {}
_results: Dict[Tuple[str, Scheme, int], object] = {}
# grid telemetry for BENCH_engine.json: wall time, compile count, cells
grid_metrics: Dict[str, float] = {}


def set_smoke() -> None:
    """Switch to tiny traces; must be called before the first trace()."""
    global SMOKE, BUDGET
    assert not _traces, "set_smoke() must run before any trace is built"
    SMOKE = True
    BUDGET = _SMOKE_BUDGET


def bucket() -> int:
    return _SMOKE_BUCKET if SMOKE else 16384


def trace(name: str):
    if name not in _traces:
        kw = dict(_SMOKE_TRACE_KW.get(name, {})) if SMOKE else {}
        _traces[name] = make_trace(name, persist_budget=BUDGET, **kw)
    return _traces[name]


def timed_sweep(run_fn):
    """Honest cold/warm timing split for a one-program sweep.

    Runs ``run_fn`` twice: the cold call pays the XLA compile(s) plus
    one execution, the warm call re-executes the already-compiled
    program.  Returns ``(cold_result, metrics)`` where ``wall_s`` is
    the warm (steady-state run) wall clock and ``compile_s`` the
    cold-minus-warm difference — BENCH_engine.json records compile
    latency *next to* the run component instead of inside it, so a
    compile-cache hit cannot mask a runtime regression and a compiler
    regression shows up in ``*_compile_s`` rather than vanishing into
    run noise (``compare.py`` gates only the ``*_wall_s`` keys).
    """
    c0, t0 = compile_count(), time.time()
    out = run_fn()
    cold = time.time() - t0
    t1 = time.time()
    run_fn()
    warm = time.time() - t1
    return out, dict(
        wall_s=round(warm, 3),
        compile_s=round(max(cold - warm, 0.0), 3),
        compiles=compile_count() - c0,
        macro_hit=round(last_macro_hit_rate(), 4),
        macro_aborts=last_macro_abort_reasons(),
    )


def _ensure_grid() -> None:
    """Run the full mixed-scheme {workload x scheme} grid once."""
    if grid_metrics:
        return
    names = list(WORKLOADS)
    traces = [trace(n) for n in names]
    configs = [PCSConfig(scheme=s) for s in SCHEMES]
    cells, m = timed_sweep(
        lambda: simulate_grid(traces, configs, bucket=bucket()))
    grid_metrics.update(
        grid_wall_s=m["wall_s"],
        grid_compile_s=m["compile_s"],
        grid_compiles=m["compiles"],
        grid_cells=len(names) * len(SCHEMES),
        grid_macro_hit=m["macro_hit"],
        grid_macro_aborts=m["macro_aborts"],
    )
    for i, n in enumerate(names):
        for j, s in enumerate(SCHEMES):
            _results[(n, s, 16)] = cells[i][j]


def result(name: str, scheme: Scheme, n_pbe: int = 16):
    key = (name, scheme, n_pbe)
    if key not in _results:
        if n_pbe == 16 and name in WORKLOADS:
            _ensure_grid()
        else:
            _results[key] = simulate_grid(
                [trace(name)], [PCSConfig(scheme=scheme, n_pbe=n_pbe)],
                bucket=bucket())[0][0]
    return _results[key]


def workloads():
    return list(WORKLOADS)


def emit(rows, header=("name", "value", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
