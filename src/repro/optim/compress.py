"""Top-k gradient compression with error feedback (distributed-opt trick).

Before the data-parallel all-reduce, each shard keeps only the largest-k
magnitudes of its gradient (per leaf) and accumulates the residual into an
error-feedback buffer that is added back next step.  Off by default; the
train driver enables it with ``--compress-ratio``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _topk_mask(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    n = x.size
    k = max(int(n * ratio), 1)
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress_grads(grads, error, ratio: float):
    """Returns (compressed_grads, new_error).  ``error`` may be None."""
    if error is None:
        error = jax.tree.map(jnp.zeros_like, grads)

    def comp(g, e):
        acc = g + e.astype(g.dtype)
        mask = _topk_mask(acc, ratio)
        kept = acc * mask
        return kept, (acc - kept)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
