from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule, global_norm)
from repro.optim.compress import topk_compress_grads

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "topk_compress_grads"]
