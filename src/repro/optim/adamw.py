"""AdamW with dtype-configurable moments, global-norm clip, schedules.

Pure-functional: state is a pytree mirroring the parameters, so it shards
with the same PartitionSpec rules (FSDP over 'data', TP over 'model') and
checkpoints through the PCS persistence tier like any other shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"            # "cosine" | "linear" | "const"


def adamw_init(cfg: AdamWConfig, params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "linear":
        return cfg.lr * warm * (1.0 - frac)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(cfg.moment_dtype)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m2 / b1t
        vh = v2 / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(cfg.moment_dtype)
        return (p - (lr * delta).astype(p.dtype)), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
