"""jit'd public wrappers with platform dispatch.

On TPU the Pallas kernels compile natively (``interpret=False``); on CPU
(this container) they run in interpret mode, where the kernel body
executes in Python — bit-identical semantics, used by the allclose tests
against the ``ref`` oracles.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.tat_lookup import tat_lookup_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tat_lookup(req_tags: jnp.ndarray, tat: jnp.ndarray,
               states: jnp.ndarray, *, block_r: int = 256
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    r = req_tags.shape[0]
    block_r = min(block_r, r)
    if r % block_r:
        return ref.tat_lookup_ref(req_tags, tat, states)
    return tat_lookup_pallas(req_tags, tat, states, block_r=block_r,
                             interpret=not _on_tpu())


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    s = q.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=not _on_tpu())


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = x.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        return ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                           interpret=not _on_tpu())
