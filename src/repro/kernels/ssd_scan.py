"""Pallas kernel: Mamba2 SSD chunked scan (dual form).

Grid: (batch, heads, chunks) with the chunk axis innermost/sequential;
the (P, N) recurrent state is VMEM scratch carried across chunks — the
inter-chunk recurrence costs one (P,N) elementwise update per chunk
while all heavy work (the Q x Q dual-attention contraction and the
Q x N / Q x P matmuls) runs on the MXU.

Layout: the wrapper reshapes to chunk-major
    x  (B, H, NC, Q, P)    dt (B, H, NC, Q)
    Bm (B, NC, Q, N)       Cm (B, NC, Q, N)
so every BlockSpec slice is contiguous.  Q=N=128 aligns the lane dim;
P=64 is the Mamba2 head dim (half-lane, still legal).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_scr, *,
            n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0]                               # () scalar decay rate (f32)
    bm = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    da = dt * a                                # (Q,) log-decay per step
    da_cum = jnp.cumsum(da)                    # (Q,)
    q = x.shape[0]

    # intra-chunk dual form: L[i,j] = exp(sum_{j<k<=i} da_k), lower-tri
    seg = da_cum[:, None] - da_cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = cm @ bm.T                          # (Q, Q)
    y = ((scores * L) * dt[None, :]) @ x        # (Q, P)

    # carried-state contribution + state update
    state = state_scr[...]                      # (P, N)
    y += jnp.exp(da_cum)[:, None] * (cm @ state.T)
    decay_to_end = jnp.exp(da_cum[-1] - da_cum)            # (Q,)
    state_new = (state * jnp.exp(da_cum[-1])
                 + (x * (dt * decay_to_end)[:, None]).T @ bm)  # (P, N)
    state_scr[...] = state_new

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _fin():
        fin_ref[0, 0] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ``repro.models.ssm.ssd_chunked``.

    x: (B, S, H, P)  dt: (B, S, H)  A: (H,)  B/C: (B, S, N)
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    br = B.reshape(b, nc, chunk, n)
    cr = C.reshape(b, nc, chunk, n)
    a32 = A.astype(jnp.float32)

    kern = functools.partial(_kernel, n_chunks=nc)
    y, fin = pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1),
                         lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, 1, chunk, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, a32, br, cr)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, fin
