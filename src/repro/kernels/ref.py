"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def tat_lookup_ref(req_tags: jnp.ndarray, tat: jnp.ndarray,
                   states: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fully-associative lookup.

    req_tags: (R,) int32 request tags
    tat:      (N,) int32 table tags
    states:   (N,) int32 entry states (0 = Empty — an Empty entry never
              matches, mirroring PBCS semantics)
    Returns (idx: (R,) int32 match index or -1, state: (R,) int32 or 0).
    """
    match = (req_tags[:, None] == tat[None, :]) & (states[None, :] != 0)
    has = jnp.any(match, axis=1)
    idx = jnp.argmax(match, axis=1)
    st = jnp.where(has, states[idx], 0)
    return jnp.where(has, idx, -1).astype(jnp.int32), st.astype(jnp.int32)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Masked softmax attention.  q/k/v: (B, H, S, D)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 128):
    """Chunked SSD oracle — delegates to the model reference (itself
    validated against the sequential recurrence in tests)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk=chunk)
