"""Pallas kernel: blockwise online-softmax (flash) attention, forward.

Grid: (batch*heads, q_blocks, k_blocks) with the k axis innermost and
sequential, so the running max / normalizer / output accumulator live in
VMEM scratch carried across k iterations.  Causal and sliding-window
masks are applied per block.

Block shapes default to (128, head_dim) — MXU-aligned for head_dim in
{64, 128, 256}; the working set per program is
``(2*block_k + 2*block_q) * d * 4B`` ≈ 0.5 MiB at d=256, far under the
16 MiB VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale       # (bq, d)
    k = k_ref[0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0].astype(jnp.float32)               # (bk, d)
    logits = q @ k.T                               # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = jnp.ones_like(logits, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr[:, None] + jnp.sum(p, axis=1)[:, None]
    acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
    m_scr[...] = m_new[:, None]

    @pl.when(ik == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (B, H, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    n_k = s // block_k
    grid = (b * h, s // block_q, n_k)

    kern = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normalizer
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
