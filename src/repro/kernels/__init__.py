"""Pallas TPU kernels for the framework's compute hot spots.

    tat_lookup       — the paper's hot loop: batched fully-associative
                       tag match against the PB's Tag Address Table
    flash_attention  — blockwise online-softmax attention (32k prefill)
    ssd_scan         — Mamba2 chunked state-space-dual scan

Each kernel ships as ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit wrapper with platform dispatch) and ``ref.py``
(pure-jnp oracle); tests sweep shapes/dtypes against the oracle with the
kernels in interpret mode (this container is CPU-only; TPU is the
compilation target).
"""
from repro.kernels.ops import flash_attention, ssd_scan, tat_lookup

__all__ = ["flash_attention", "ssd_scan", "tat_lookup"]
