"""Pallas kernel: batched fully-associative TAT lookup.

The PB's hot loop (PBCS tag check, Section V-C) as a TPU kernel: a block
of request tags is compared against the whole Tag Address Table resident
in VMEM; the match reduction maps onto the VPU's 8x128 lanes.  Used by
the vectorized PCS simulator when scoring large request batches.

Tiling: requests are tiled in blocks of ``block_r``; the TAT (tags +
states) is small (16-1024 entries) and fully VMEM-resident, broadcast to
every program.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(req_ref, tat_ref, st_ref, idx_ref, out_st_ref):
    req = req_ref[...]                       # (block_r,)
    tat = tat_ref[...]                       # (n,)
    st = st_ref[...]                         # (n,)
    match = (req[:, None] == tat[None, :]) & (st[None, :] != 0)
    has = jnp.any(match, axis=1)
    # argmax over the entry axis (first match wins, like priority encode)
    idx = jnp.argmax(match, axis=1).astype(jnp.int32)
    idx_ref[...] = jnp.where(has, idx, -1)
    out_st_ref[...] = jnp.where(has, jnp.take(st, idx), 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def tat_lookup_pallas(req_tags: jnp.ndarray, tat: jnp.ndarray,
                      states: jnp.ndarray, *, block_r: int = 256,
                      interpret: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    r = req_tags.shape[0]
    n = tat.shape[0]
    assert r % block_r == 0, (r, block_r)
    grid = (r // block_r,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(req_tags, tat, states)
