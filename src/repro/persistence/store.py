"""The two persistence tiers behind the PCS checkpoint manager.

``HostBufferTier``  — the cluster analogue of the switch's Persistent
Buffer: a bounded in-memory store adjacent to the accelerator.  Durability
of an ack is provided by K-replication across failure domains in a real
deployment; here replication is modeled by ``replicas`` metadata so tests
can fail individual replicas.

``DurableStore``    — the PM endpoint analogue: a slow, durable object
store (directory of files, fsync'd), with versioned, atomic writes that
reject stale versions (the paper's PM write-order rule).
"""
from __future__ import annotations

import io
import os
import pickle
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np


def _serialize(tree: Any) -> bytes:
    buf = io.BytesIO()
    pickle.dump(tree, buf, protocol=pickle.HIGHEST_PROTOCOL)
    return buf.getvalue()


def _deserialize(raw: bytes) -> Any:
    return pickle.loads(raw)


class HostBufferTier:
    """Bounded host-memory buffer holding (shard, version) -> payload."""

    def __init__(self, capacity_bytes: int = 1 << 30, replicas: int = 2):
        self.capacity_bytes = capacity_bytes
        self.replicas = replicas
        self._data: Dict[Tuple[str, int], bytes] = {}
        self._alive: Dict[Tuple[str, int], int] = {}
        self._lock = threading.Lock()

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._data.values())

    def put(self, shard: str, version: int, payload: bytes) -> bool:
        with self._lock:
            used = sum(len(v) for v in self._data.values())
            if used + len(payload) > self.capacity_bytes:
                return False
            self._data[(shard, version)] = payload
            self._alive[(shard, version)] = self.replicas
            return True

    def get(self, shard: str, version: int) -> Optional[bytes]:
        with self._lock:
            if self._alive.get((shard, version), 0) <= 0:
                return None
            return self._data.get((shard, version))

    def newest(self, shard: str) -> Optional[Tuple[int, bytes]]:
        with self._lock:
            versions = [v for (s, v), alive in self._alive.items()
                        if s == shard and alive > 0 and (s, v) in self._data]
            if not versions:
                return None
            v = max(versions)
            return v, self._data[(shard, v)]

    def drop(self, shard: str, version: int) -> None:
        with self._lock:
            self._data.pop((shard, version), None)
            self._alive.pop((shard, version), None)

    def fail_replica(self, shard: str, version: int) -> None:
        """Simulate losing one replica of an entry (node failure)."""
        with self._lock:
            if (shard, version) in self._alive:
                self._alive[(shard, version)] -= 1
                if self._alive[(shard, version)] <= 0:
                    self._data.pop((shard, version), None)

    def entries(self):
        with self._lock:
            return [(s, v) for (s, v), a in self._alive.items() if a > 0]

    def crash_volatile(self) -> None:
        """Power loss of the *volatile* routing state: the buffer itself
        survives (battery/NV analogue) — nothing to do, mirrors PB."""


class DurableStore:
    """Filesystem-backed durable endpoint with versioned atomic writes."""

    def __init__(self, root: str, write_delay_s: float = 0.0):
        self.root = root
        self.write_delay_s = write_delay_s
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self.writes_applied = 0
        self.stale_rejected = 0

    def _path(self, shard: str) -> str:
        return os.path.join(self.root, shard.replace("/", "_") + ".ckpt")

    def version_of(self, shard: str) -> int:
        p = self._path(shard)
        if not os.path.exists(p):
            return -1
        with open(p, "rb") as f:
            return int.from_bytes(f.read(8), "little")

    def write(self, shard: str, version: int, payload: bytes) -> bool:
        """Atomic versioned write; returns False for stale versions."""
        if self.write_delay_s:
            time.sleep(self.write_delay_s)
        with self._lock:
            if self.version_of(shard) > version:
                self.stale_rejected += 1
                return False
            fd, tmp = tempfile.mkstemp(dir=self.root)
            with os.fdopen(fd, "wb") as f:
                f.write(version.to_bytes(8, "little"))
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._path(shard))
            self.writes_applied += 1
            return True

    def read(self, shard: str) -> Optional[Tuple[int, bytes]]:
        p = self._path(shard)
        if not os.path.exists(p):
            return None
        with open(p, "rb") as f:
            raw = f.read()
        return int.from_bytes(raw[:8], "little"), raw[8:]

    def shards(self):
        return [f[:-5] for f in os.listdir(self.root) if f.endswith(".ckpt")]
