"""PCS checkpoint manager: the paper's PB state machine over train-state shards.

Mapping (DESIGN.md §2, Layer B):

    persist (clflush+mfence)  -> checkpoint write of one sharded slice
    PB entry Dirty/Drain/Empty-> ShardState per (shard, version)
    ack at first switch       -> persist() returns once the host buffer
                                 holds the payload (training resumes)
    background drain          -> a drainer thread uploads buffer->store
    write order               -> DurableStore rejects stale versions; the
                                 drain queue is FIFO per shard
    crash consistency         -> a buffer entry is freed only after the
                                 store confirms the write (drain ack)
    Read Forwarding           -> restore() serves from the buffer when the
                                 newest acked version still lives there
    write coalescing          -> a newer buffered version of a shard
                                 supersedes an undrained older one (the
                                 older drain is elided)
    recovery (drain-all)      -> on restart, every surviving buffer entry
                                 is re-drained; stale writes are rejected

Schemes mirror the paper: NOPB (write-through to the store, ack on store
fsync), PB (ack at buffer, drain immediately), PB_RF (ack at buffer,
drain lazily above a threshold -> read forwarding + coalescing).
"""
from __future__ import annotations

import enum
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.params import (DEFAULT_DRAIN_PRESET,
                               DEFAULT_DRAIN_THRESHOLD, DrainPolicy,
                               PBPolicy, SCHEME_NAMES, Scheme,
                               epoch_index, resolve_epoch,
                               shared_boundaries)
from repro.persistence.store import DurableStore, HostBufferTier, _deserialize, _serialize

# The checkpoint tier speaks the same scheme vocabulary as the timed
# engine and the untimed oracle: names and drain thresholds come from the
# shared policy definitions, so the layers can no longer drift.
PersistScheme = enum.Enum(
    "PersistScheme", {s.name: SCHEME_NAMES[s] for s in Scheme})


class ShardState(enum.Enum):
    DIRTY = "dirty"
    DRAIN = "drain"
    EMPTY = "empty"


class PCSCheckpointManager:
    def __init__(self, buffer: HostBufferTier, store: DurableStore, *,
                 scheme: PersistScheme = PersistScheme.PB_RF,
                 policy: Optional[PBPolicy] = None,
                 drain_threshold: float = DEFAULT_DRAIN_THRESHOLD,
                 drain_preset: float = DEFAULT_DRAIN_PRESET,
                 sync_drain: bool = False):
        self.buffer = buffer
        self.store = store
        self.scheme = scheme
        # The checkpoint tier consumes the same declarative PBPolicy as
        # the engine and the oracle; the legacy float knobs forward into
        # a default policy (same shim as PCSConfig).  The drain fractions
        # apply to buffer *bytes* instead of PBE counts; the tenant-quota
        # / victim fields are inert here until the tier grows a tenant
        # axis (single-host checkpoint streams today).
        if policy is None:
            policy = PBPolicy(drain=DrainPolicy(threshold=drain_threshold,
                                                preset=drain_preset))
        # Epoched host-side policy (first step of carrying quotas into
        # the checkpoint tier): any Schedule on the policy is honoured
        # with its boundaries read as PERSIST INDICES — the tier's
        # logical clock — so a quota/threshold step lands at an exact
        # acked-persist count, mirroring schedule_crash's after_persists
        # determinism despite the asynchronous drainer.
        self._base_policy = policy
        self._epoch_bounds = shared_boundaries(
            policy.drain.threshold, policy.drain.preset,
            policy.drain.latency_target_ns, policy.alloc.tenant_quota)
        self._epoch = -1
        self._set_epoch(0)
        self.sync_drain = sync_drain
        self._states: Dict[Tuple[str, int], ShardState] = {}
        self._lru: Dict[Tuple[str, int], float] = {}
        self._tenant_of: Dict[Tuple[str, int], int] = {}
        self._lock = threading.RLock()
        self._q: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self.stats = {"persists": 0, "acks": 0, "drains": 0, "coalesces": 0,
                      "restore_forwarded": 0, "restore_from_store": 0,
                      "stalls": 0, "lost_after_crash": 0}
        self._crashed = False
        self._crash_after: Optional[int] = None
        self._drainer = None
        if not sync_drain and scheme != PersistScheme.NOPB:
            self._start_drainer()

    def _set_epoch(self, epoch: int) -> None:
        """Collapse the base policy to its value during ``epoch``
        (``params.resolve_epoch`` — the same resolution rule the engine
        lowering and the oracle use, so the tiers cannot drift)."""
        self._epoch = int(epoch)
        pol = resolve_epoch(self._base_policy, self._epoch)
        self.policy = pol
        self.drain_threshold = pol.drain.threshold
        self.drain_preset = pol.drain.preset
        self._quota = pol.alloc.tenant_quota

    def _start_drainer(self) -> None:
        """Spawn the background drain loop — refusing to double-spawn.

        One *active* drain loop per queue: if the tracked drainer is
        alive and has not been told to stop, this is a no-op.  A
        previous drainer that is alive but already stopping (a slow
        ``DurableStore`` write outliving ``crash()``'s 1 s join) is not
        a conflict: each thread loops on its own private stop event,
        captured at spawn, so the stale thread exits as soon as its
        in-flight write returns and can never consume from the new
        queue — while the fresh thread gets a fresh event.
        """
        if (self._drainer is not None and self._drainer.is_alive()
                and not self._stop.is_set()):
            return
        self._stop = threading.Event()
        # the queue is bound at spawn too: a stale thread keeps polling
        # the *old* (abandoned) queue, never its successor's
        self._drainer = threading.Thread(target=self._drain_loop,
                                         args=(self._stop, self._q),
                                         name="pcs-ckpt-drainer",
                                         daemon=True)
        self._drainer.start()

    # ------------------------------------------------------------- persist
    def persist(self, shard: str, version: int, tree: Any,
                tenant: int = 0) -> None:
        """Make (shard, version) durable.  Returns when the persistent
        domain holds it: store fsync under NOPB, buffer ack under PB/RF.

        ``tenant`` attributes the entry for the per-tenant quota
        drain-down (inert when the policy carries no ``tenant_quota``).
        """
        # crash window (mirrors the engine's crash_at_ns): the power is
        # lost right before persist #(crash_after + 1), so exactly
        # crash_after persists are acked — a deterministic logical crash
        # point despite the asynchronous drainer.  The flag flips under
        # the lock; the drainer join happens outside it (the drainer
        # takes the same lock to finish its in-flight drain).
        fire = False
        with self._lock:
            # persist-indexed epoch advance: this persist executes under
            # epoch_of(#persists so far) — the same <=-gate as the
            # engine's issue-clock selection, on the tier's logical clock
            if self._epoch_bounds:
                ep = epoch_index(self._epoch_bounds,
                                 self.stats["persists"])
                if ep != self._epoch:
                    self._set_epoch(ep)
            if (self._crash_after is not None and not self._crashed
                    and self.stats["persists"] >= self._crash_after):
                self._crashed = fire = True
            if self._crashed:
                # machine is off: the write never reaches the switch
                self.stats["lost_after_crash"] += 1
                if not fire:
                    return
        if fire:
            self.crash()
            return
        payload = _serialize(tree)
        self.stats["persists"] += 1
        if self.scheme == PersistScheme.NOPB:
            self.store.write(shard, version, payload)
            self.stats["acks"] += 1
            return

        with self._lock:
            # write coalescing (PB_RF): an undrained older version of the
            # same shard is superseded — its drain is elided entirely.
            if self.scheme == PersistScheme.PB_RF:
                for (s, v), st in list(self._states.items()):
                    if s == shard and st == ShardState.DIRTY and v < version:
                        self._states[(s, v)] = ShardState.EMPTY
                        self.buffer.drop(s, v)
                        self.stats["coalesces"] += 1

            while not self.buffer.put(shard, version, payload):
                # buffer full: drain LRU dirty entries (stall, V-D1)
                self.stats["stalls"] += 1
                if not self._evict_one_locked():
                    raise RuntimeError(
                        "host buffer exhausted and nothing drainable")
            self._states[(shard, version)] = ShardState.DIRTY
            self._lru[(shard, version)] = time.monotonic()
            self._tenant_of[(shard, version)] = tenant
            self.stats["acks"] += 1

            if self.scheme == PersistScheme.PB:
                self._start_drain_locked(shard, version)
            else:
                self._quota_drain_locked(tenant)
                self._rf_drain_down_locked()
        if self.sync_drain:
            self.drain_all(wait=True)

    # --------------------------------------------------------------- drain
    def _start_drain_locked(self, shard: str, version: int) -> None:
        if self._states.get((shard, version)) != ShardState.DIRTY:
            return
        self._states[(shard, version)] = ShardState.DRAIN
        self.stats["drains"] += 1
        if self.sync_drain or self._drainer is None:
            self._drain_one(shard, version)
        else:
            self._q.put((shard, version))

    def _quota_drain_locked(self, tenant: int) -> None:
        """Per-tenant quota drain-down: while ``tenant`` holds more
        DIRTY entries than its active-epoch quota, start draining its
        LRU dirty entry — the host-side analogue of the engine's
        per-tenant drain scope.  Drain *initiation* is synchronous
        (DIRTY -> DRAIN under the lock), so the drain counts stay
        deterministic even with the asynchronous drainer."""
        if self._quota is None:
            return
        q = int(self._quota[tenant % len(self._quota)])
        while True:
            dirty = sorted(
                [k for k, st in self._states.items()
                 if st == ShardState.DIRTY
                 and self._tenant_of.get(k, 0) == tenant],
                key=lambda k: self._lru.get(k, 0.0))
            if len(dirty) <= q:
                return
            self._start_drain_locked(*dirty[0])

    def _rf_drain_down_locked(self) -> None:
        cap = self.buffer.capacity_bytes
        if self.buffer.used_bytes <= self.drain_threshold * cap:
            return
        dirty = sorted(
            [k for k, st in self._states.items() if st == ShardState.DIRTY],
            key=lambda k: self._lru.get(k, 0.0))
        for key in dirty:
            if self.buffer.used_bytes <= self.drain_preset * cap:
                break
            self._start_drain_locked(*key)

    def _evict_one_locked(self) -> bool:
        dirty = sorted(
            [k for k, st in self._states.items() if st == ShardState.DIRTY],
            key=lambda k: self._lru.get(k, 0.0))
        if not dirty:
            # everything already draining; wait for one to complete
            draining = [k for k, st in self._states.items()
                        if st == ShardState.DRAIN]
            if not draining:
                return False
            key = draining[0]
            self._lock.release()
            try:
                for _ in range(10_000):
                    if self._states.get(key) != ShardState.DRAIN:
                        return True
                    time.sleep(0.001)
            finally:
                self._lock.acquire()
            return True
        self._start_drain_locked(*dirty[0])
        if self.sync_drain or self._drainer is None:
            return True
        # give the drainer a moment (ack-priority analogue)
        self._lock.release()
        try:
            time.sleep(0.002)
        finally:
            self._lock.acquire()
        return True

    def _drain_one(self, shard: str, version: int) -> None:
        payload = self.buffer.get(shard, version)
        if payload is not None:
            self.store.write(shard, version, payload)  # stale -> rejected
        with self._lock:
            # crash consistency: free ONLY after the store ack
            self._states[(shard, version)] = ShardState.EMPTY
            self.buffer.drop(shard, version)

    def _drain_loop(self, stop: threading.Event, q: "queue.Queue") -> None:
        # `stop` and `q` are this thread's private bindings (see
        # _start_drainer): the event stays set once set and the queue
        # reference never changes, so a stale loop can neither wake up
        # again nor consume / task_done on a successor's queue.
        while not stop.is_set():
            try:
                shard, version = q.get(timeout=0.05)
            except queue.Empty:
                continue
            self._drain_one(shard, version)
            q.task_done()

    def drain_all(self, wait: bool = True) -> None:
        with self._lock:
            for (s, v), st in list(self._states.items()):
                if st == ShardState.DIRTY:
                    self._start_drain_locked(s, v)
        if wait and self._drainer is not None:
            self._q.join()

    # -------------------------------------------------------------- restore
    def restore(self, shard: str) -> Optional[Tuple[int, Any]]:
        """Read Forwarding: newest version, from the buffer if it still
        lives there, else from the durable store."""
        hit = self.buffer.newest(shard)
        rec = self.store.read(shard)
        if hit is not None and (rec is None or hit[0] >= rec[0]):
            self.stats["restore_forwarded"] += 1
            return hit[0], _deserialize(hit[1])
        if rec is None:
            return None
        self.stats["restore_from_store"] += 1
        return rec[0], _deserialize(rec[1])

    # ------------------------------------------------------------- recovery
    def schedule_crash(self, after_persists: int) -> None:
        """Arm a deterministic crash window: power is lost right before
        persist number ``after_persists + 1`` reaches the switch, i.e.
        exactly ``after_persists`` persists get acked.  The checkpoint
        analogue of the engine's ``crash_at_ns`` — a crash scheduled at a
        persist index instead of a wall-clock instant."""
        if after_persists < 0:
            raise ValueError("after_persists must be >= 0")
        self._crash_after = after_persists

    def crash(self) -> None:
        """Process crash: queue (volatile routing state) is lost; buffer
        and store survive.  Until :meth:`recover`, further persists are
        dropped (the machine is off)."""
        self._crashed = True
        self._stop.set()
        if self._drainer is not None and self._drainer is not \
                threading.current_thread():
            self._drainer.join(timeout=1.0)
        self._q = queue.Queue()

    def recover(self) -> int:
        """Reboot: treat every surviving buffer entry as Dirty and drain
        all (Section V-D4).  Stale versions are rejected by the store.
        Restarts the drainer, so the manager is usable again afterwards.
        Returns the number of entries re-drained."""
        n = 0
        for shard, version in self.buffer.entries():
            payload = self.buffer.get(shard, version)
            if payload is not None:
                self.store.write(shard, version, payload)
                n += 1
            self.buffer.drop(shard, version)
            self._states[(shard, version)] = ShardState.EMPTY
        self._crashed = False
        self._crash_after = None
        if not self.sync_drain and self.scheme != PersistScheme.NOPB:
            self._start_drainer()
        return n

    def close(self) -> None:
        self.drain_all(wait=True)
        self._stop.set()
        if self._drainer is not None:
            self._drainer.join(timeout=2.0)
