from repro.persistence.store import DurableStore, HostBufferTier
from repro.persistence.manager import (PCSCheckpointManager, PersistScheme,
                                       ShardState)

__all__ = ["DurableStore", "HostBufferTier", "PCSCheckpointManager",
           "PersistScheme", "ShardState"]
