"""gemma3-12b [dense] — 5:1 local:global, 128k ctx [hf:google/gemma-3; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, head_dim 256,
sliding window 1024, qk-norm, global rope theta 1e6.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
        vocab=262144, head_dim=256, window=1024, qk_norm=True,
        rope_theta=1_000_000.0,
        block_pattern=tuple([LayerSpec("swa")] * 5 + [LayerSpec("attn")]),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16, window=8, qk_norm=True,
        block_pattern=tuple([LayerSpec("swa")] * 5 + [LayerSpec("attn")]),
        remat=False, dtype=jnp.float32)
