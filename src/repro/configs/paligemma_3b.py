"""paligemma-3b [vlm] — SigLIP + gemma prefix-LM [arXiv:2407.07726; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.  The SigLIP
vision tower is a STUB: ``input_specs()`` supplies 256 precomputed patch
embeddings as the (bidirectional) prefix.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
        vocab=257216, head_dim=256, frontend="vision", frontend_seq=256,
        block_pattern=(LayerSpec("attn"),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="paligemma-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=512, head_dim=16,
        frontend="vision", frontend_seq=8,
        block_pattern=(LayerSpec("attn"),), remat=False, dtype=jnp.float32)
