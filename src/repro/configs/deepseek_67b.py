"""deepseek-67b [dense] — llama architecture [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400, head_dim 128.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
        vocab=102400, head_dim=128,
        block_pattern=(LayerSpec("attn"),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=512, head_dim=16,
        block_pattern=(LayerSpec("attn"),), remat=False, dtype=jnp.float32)
