"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, sliding window
4096 on every layer.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=32000, head_dim=128, window=4096, n_experts=8, top_k=2,
        block_pattern=(LayerSpec("swa", moe=True),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16, window=8, n_experts=4, top_k=2,
        block_pattern=(LayerSpec("swa", moe=True),),
        remat=False, dtype=jnp.float32)
