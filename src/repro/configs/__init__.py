"""Assigned-architecture registry: ``get_config(arch_id)`` / ``ARCHS``.

Each ``<id>.py`` module exports

    config()        -> the full published configuration
    smoke_config()  -> a reduced same-family configuration for CPU tests

IDs use the dashed names of the assignment; module files use underscores.
"""
from __future__ import annotations

import importlib
from typing import List

ARCHS: List[str] = [
    "seamless-m4t-large-v2",
    "gemma2-2b",
    "deepseek-67b",
    "smollm-135m",
    "gemma3-12b",
    "jamba-1.5-large-398b",
    "phi3.5-moe-42b",
    "mixtral-8x7b",
    "mamba2-1.3b",
    "paligemma-3b",
]

_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b",
    "jamba-1.5-large": "jamba-1.5-large-398b",
}


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str, *, smoke: bool = False):
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCHS}")
    mod = _module(arch_id)
    return mod.smoke_config() if smoke else mod.config()
