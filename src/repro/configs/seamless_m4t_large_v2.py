"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.  The 24 layers
are split 12 encoder + 12 decoder (the published model pairs a speech
encoder with a text decoder); the audio frontend (conformer feature
extractor) is a STUB — ``input_specs()`` supplies precomputed frame
embeddings at a 4x frame-to-token rate.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        n_layers=12, n_enc_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        vocab=256206, frontend="audio",
        block_pattern=(LayerSpec("attn"),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="seamless-smoke", n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        frontend="audio", block_pattern=(LayerSpec("attn"),),
        remat=False, dtype=jnp.float32)
