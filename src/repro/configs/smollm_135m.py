"""smollm-135m [dense] — small llama [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, head_dim 64.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
        vocab=49152, head_dim=64,
        block_pattern=(LayerSpec("attn"),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="smollm-smoke", n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
        d_ff=96, vocab=512, head_dim=16,
        block_pattern=(LayerSpec("attn"),), remat=False, dtype=jnp.float32)
