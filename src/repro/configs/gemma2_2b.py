"""gemma2-2b [dense] — local:global 1:1, logit softcaps [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim 256,
sliding window 4096, attn softcap 50, final softcap 30.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
        vocab=256000, head_dim=256, window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        block_pattern=(LayerSpec("swa"), LayerSpec("attn")),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16, window=8,
        attn_softcap=50.0, final_softcap=30.0,
        block_pattern=(LayerSpec("swa"), LayerSpec("attn")),
        remat=False, dtype=jnp.float32)
