"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, every layer MoE.
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
        vocab=32064, head_dim=128, n_experts=16, top_k=2,
        block_pattern=(LayerSpec("attn", moe=True),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="phi-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=512, head_dim=16, n_experts=4, top_k=2,
        block_pattern=(LayerSpec("attn", moe=True),),
        remat=False, dtype=jnp.float32)
