"""mamba2-1.3b [ssm] — SSD, attention-free [arXiv:2405.21060; unverified].

48L d_model=2048 vocab=50280 ssm_state=128, expand 2, head_dim 64,
no feed-forward sublayer (d_ff=0).
"""
from repro.models.transformer import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        block_pattern=(LayerSpec("ssm"),),
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="mamba2-smoke", n_layers=3, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=512, ssm_state=16, ssm_head_dim=16,
        block_pattern=(LayerSpec("ssm"),), remat=False, dtype=jnp.float32)
