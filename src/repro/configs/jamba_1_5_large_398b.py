"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 on
every other layer, one attention layer per 8 (1:7 attn:mamba).  The SSD
mixer is Mamba2 (the published model uses Mamba1; SSD is the TPU-native
chunked form — recorded in DESIGN.md).
"""
from repro.models.transformer import LayerSpec, ModelConfig

_BLOCK = (
    LayerSpec("ssm"), LayerSpec("ssm", moe=True),
    LayerSpec("ssm"), LayerSpec("ssm", moe=True),
    LayerSpec("attn"), LayerSpec("ssm", moe=True),
    LayerSpec("ssm"), LayerSpec("ssm", moe=True),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
        vocab=65536, head_dim=128, n_experts=16, top_k=2,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        block_pattern=_BLOCK,
    )


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="jamba-smoke", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, head_dim=16, n_experts=4, top_k=2,
        ssm_state=16, ssm_head_dim=16,
        block_pattern=tuple(
            LayerSpec(s.kind, s.moe) for s in _BLOCK),
        remat=False, dtype=jnp.float32)
