"""Deterministic synthetic LM data pipeline.

Seeded, shardable, and checkpointable: the cursor (global step) is the
only state, so restoring a checkpoint resumes the exact token stream.
Batches are Zipf-distributed token ids with a simple Markov structure so
the loss actually decreases (useful for the end-to-end examples).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class SyntheticLMDataset:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, d_model: Optional[int] = None,
                 frontend: Optional[str] = None, frontend_seq: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.d_model = d_model
        self.frontend = frontend
        self.frontend_seq = frontend_seq
        self.step = 0
        # fixed Markov shift makes next-token partially predictable
        self._shift = 7

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "seed mismatch on restore"

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed << 20) ^ step)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng(self.step)
        self.step += 1
        b, s = self.global_batch, self.seq_len
        base = rng.zipf(1.3, size=(b, s // 8 + 1)).clip(1, self.vocab - 1)
        toks = np.repeat(base, 8, axis=1)[:, :s]
        toks = (toks + self._shift * np.arange(s)[None, :]) % self.vocab
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        out = {"tokens": toks.astype(np.int32),
               "labels": labels.astype(np.int32)}
        if self.frontend == "audio":
            out["enc_embeds"] = rng.standard_normal(
                (b, s // 4, self.d_model), dtype=np.float32) * 0.02
        if self.frontend == "vision":
            out["prefix_embeds"] = rng.standard_normal(
                (b, self.frontend_seq, self.d_model), dtype=np.float32) * 0.02
        return out
