"""jit-able train / prefill / decode step functions."""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_update
from repro.optim.compress import topk_compress_grads


def make_train_step(cfg: T.ModelConfig, opt_cfg: AdamWConfig,
                    compress_ratio: float = 0.0, microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches`` > 1 accumulates gradients over batch slices with a
    ``lax.scan`` — peak activation residency drops by the same factor
    (only one microbatch's remat-saved inputs are live during its
    backward).  Also the straggler-catchup mechanism's lever.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch))(params)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, bi):
                loss_a, g_a = carry
                li, gi = grads_of(params, bi)
                return (loss_a + li,
                        jax.tree.map(jnp.add, g_a, gi)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros(()), zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        if compress_ratio > 0.0:
            grads, err = topk_compress_grads(
                grads, opt_state.get("err"), compress_ratio)
            opt_state = dict(opt_state, err=err)
        params, opt_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: T.ModelConfig, max_len: int):
    def step(params, batch):
        return T.prefill(cfg, params, batch, max_len)
    return step


def make_decode_step(cfg: T.ModelConfig):
    def step(params, tokens_last, caches, pos0, enc_out=None, enc_pos=None):
        return T.decode_step(cfg, params, tokens_last, caches, pos0=pos0,
                             enc_out=enc_out, enc_pos=enc_pos)
    return step
