"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def make_host_mesh():
    """A trivial 1x1 mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
