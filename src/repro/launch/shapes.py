"""Assigned input shapes and per-architecture applicability."""
from __future__ import annotations

import dataclasses
from typing import List

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: Shape) -> bool:
    """long_500k needs a sub-quadratic path (SSM or sliding-window); pure
    full-attention archs skip it (recorded in DESIGN.md §Arch-applicability).
    Decode shapes would be skipped for encoder-only archs (none assigned).
    """
    if shape.name == "long_500k":
        return not cfg.full_attention_only
    return True


def cells(cfg: ModelConfig) -> List[Shape]:
    return [s for s in SHAPES.values() if applicable(cfg, s)]
