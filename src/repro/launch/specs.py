"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs`` mirrors exactly what the data pipeline / serving frontend
produce; the dry-run lowers against these, so every (arch x shape x mesh)
cell is exercised without touching device memory.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.launch.shapes import Shape
from repro.models import transformer as T

AUDIO_FRAME_RATE = 4  # tokens per encoder frame (stub conformer stride)


def train_batch_specs(cfg: T.ModelConfig, shape: Shape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch = {
        "tokens": sd((b, s), jnp.int32),
        "labels": sd((b, s), jnp.int32),
    }
    if cfg.is_enc_dec:
        batch["enc_embeds"] = sd((b, s // AUDIO_FRAME_RATE, cfg.d_model),
                                 jnp.float32)
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = sd((b, cfg.frontend_seq, cfg.d_model),
                                    jnp.float32)
    return batch


def params_specs(cfg: T.ModelConfig):
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0)))


def opt_state_specs(cfg: T.ModelConfig, opt_cfg):
    from repro.optim import adamw_init
    p = params_specs(cfg)
    return jax.eval_shape(lambda: adamw_init(opt_cfg, p))


def decode_specs(cfg: T.ModelConfig, shape: Shape) -> Tuple:
    """(tokens_last, caches, pos0, enc_out?, enc_pos?) specs for decode."""
    b, s = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    caches = jax.eval_shape(lambda: T.init_caches(cfg, b, s))
    out = {
        "tokens_last": sd((b, 1), jnp.int32),
        "caches": caches,
        "pos0": sd((), jnp.int32),
    }
    if cfg.is_enc_dec:
        out["enc_out"] = sd((b, s // AUDIO_FRAME_RATE, cfg.d_model), cfg.dtype)
        out["enc_pos"] = sd((s // AUDIO_FRAME_RATE,), jnp.int32)
    return out
