"""End-to-end training driver with PCS-tier checkpointing.

Runs any ``--arch`` (full or ``--smoke`` reduced config) on the local
device(s), persisting train state through the PCS checkpoint manager
(``--scheme nopb|pb|pb_rf``), with failure detection, elastic remesh
planning and straggler mitigation wired in.  This is the driver used by
``examples/train_quickstart.py`` and the crash-recovery integration test.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50 --ckpt-every 10 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.launch.steps import make_train_step
from repro.persistence import (DurableStore, HostBufferTier,
                               PCSCheckpointManager, PersistScheme)
from repro.runtime import FailureDetector, StragglerMitigator, plan_mesh


def save_state(mgr: PCSCheckpointManager, version: int, params, opt_state,
               data_state: dict) -> float:
    """Persist the train state as per-leaf shards; returns persist seconds.

    Each leaf is its own shard (the cluster analogue of a cache line):
    write coalescing and read forwarding then operate per-leaf.
    """
    t0 = time.time()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        {"params": params, "opt": opt_state})
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        mgr.persist(name, version, np.asarray(leaf))
    mgr.persist("__meta__", version, {"data": data_state, "version": version})
    return time.time() - t0


def restore_state(mgr: PCSCheckpointManager, params, opt_state):
    """Restore the newest consistent state; returns (version, p, o, meta)."""
    meta = mgr.restore("__meta__")
    if meta is None:
        return None
    version = meta[1]["version"]
    flat, tdef = jax.tree_util.tree_flatten_with_path(
        {"params": params, "opt": opt_state})
    leaves = []
    for path, leaf in flat:
        rec = mgr.restore(jax.tree_util.keystr(path))
        assert rec is not None, f"missing shard {path}"
        got_v, arr = rec
        assert got_v >= version, (path, got_v, version)
        leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree.structure({"params": params, "opt": opt_state}), leaves)
    return version, tree["params"], tree["opt"], meta[1]["data"]


def make_manager(args) -> PCSCheckpointManager:
    scheme = PersistScheme(args.scheme)
    buffer = HostBufferTier(capacity_bytes=args.buffer_mb << 20)
    store = DurableStore(args.ckpt_dir, write_delay_s=args.store_delay_ms / 1e3)
    return PCSCheckpointManager(buffer, store, scheme=scheme)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--scheme", default="pb_rf",
                    choices=["nopb", "pb", "pb_rf"])
    ap.add_argument("--buffer-mb", type=int, default=256)
    ap.add_argument("--store-delay-ms", type=float, default=20.0,
                    help="durable-store write latency (object-store analogue)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-ratio", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    key = jax.random.key(0)
    params = T.init_params(cfg, key)
    opt_state = adamw_init(opt_cfg, params)
    data = SyntheticLMDataset(cfg.vocab, args.seq, args.batch,
                              d_model=cfg.d_model, frontend=cfg.frontend,
                              frontend_seq=cfg.frontend_seq)

    mgr = make_manager(args)
    start = 0
    if args.resume:
        rec = restore_state(mgr, params, opt_state)
        if rec is not None:
            start, params, opt_state, data_state = rec
            data.restore(data_state)
            print(f"resumed at step {start} "
                  f"(forwarded={mgr.stats['restore_forwarded']}, "
                  f"store={mgr.stats['restore_from_store']})")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      compress_ratio=args.compress_ratio))
    detector = FailureDetector(["node0"])
    straggler = StragglerMitigator()

    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        detector.heartbeat("node0")
        if straggler.observe(dt):
            print(f"  straggler flagged at step {step} ({dt:.2f}s)")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            psec = save_state(mgr, step + 1, params, opt_state, data.state())
            print(f"step {step+1:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"step_s {dt:.2f} persist_s {psec:.3f}", flush=True)
    mgr.close()
    print("train done; persistence stats:", mgr.stats)
    # elastic plan sanity (what we would do on chip loss)
    plan = plan_mesh(255, model_parallel=16)
    print("elastic plan if 1 chip of 256 dies:", plan)


if __name__ == "__main__":
    main()
