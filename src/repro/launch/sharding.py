"""Sharding rules: FSDP over 'data', tensor-parallel over 'model'.

Rules are path-based over the parameter pytree and divisibility-aware:
an axis is only sharded when its size divides the mesh axis, otherwise it
falls back to replication (e.g. seamless' vocab of 256206 is not
16-divisible, so its embedding shards d_model instead).

KV caches shard their *sequence* dimension over 'model' (+'data' for the
single-request long-context shape): the assigned GQA configs have 1-16 KV
heads, which cannot split over a 16-way model axis, while 32k/500k
sequences always can.  GSPMD inserts the softmax partial-reductions this
implies.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

# Perf-iteration knobs (EXPERIMENTS.md §Perf).  Defaults = the baseline
# FSDP('data') x TP('model') layout; the dry-run CLI overrides via --set.
FLAGS = {
    # experts on the model axis (expert parallelism) instead of d_ff TP
    "moe_expert_parallel": False,
    # dense FFN/attn weights pure-TP (replicated over data, no FSDP
    # all-gathers; only viable for small models)
    "dense_pure_tp": False,
    # activation sharding between blocks: 'none' (replicated over model),
    # 'seq' (sequence parallelism: S over 'model'), or 'd' (feature dim
    # over 'model') — §Perf iteration 2
    "act_shard": "none",
    # batch (and activations) sharded over BOTH mesh axes: pure-FSDP
    # data parallelism, no tensor parallelism (use with fsdp_same_dim)
    "batch_both": False,
    # stack the FSDP ('data') shards on the SAME dim as TP ('model')
    # instead of the contraction dim: leaves the partitioner no resolution
    # other than a weight all-gather (vs partial-sum all-reducing the much
    # larger activations) — see EXPERIMENTS.md §Perf iteration 1
    "fsdp_same_dim": False,
}


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name]


def _ok(mesh, dim_size: int, axis) -> bool:
    return axis is not None and dim_size % _axis_size(mesh, axis) == 0


def _maybe(mesh, dim: int, axis):
    return axis if _ok(mesh, dim, axis) else None


def param_spec(mesh, path, leaf) -> P:
    """PartitionSpec for one parameter leaf given its tree path."""
    names = [getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))
             for k in path]
    names = [str(n) for n in names]
    shape = leaf.shape
    dp = "data"

    def dim(i):  # handles the stacked leading reps dim
        return shape[i]

    stacked = "blocks" in names or "enc_blocks" in names
    lead: Tuple = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    joined = ".".join(names)
    if "embed" in names and "table" in names:
        v, d = shape
        if FLAGS["fsdp_same_dim"] and v % _axis_size(mesh, ("model", dp)) == 0:
            return P(("model", dp), None)
        if v % _axis_size(mesh, "model") == 0:
            if FLAGS["fsdp_same_dim"]:
                return P("model", None)
            return P(_maybe(mesh, v, "model"), _maybe(mesh, d, dp))
        return P(None, _maybe(mesh, d, "model"))
    if len(body) <= 1:  # norms, biases, A_log, dt_bias, step...
        return P(*lead, *([None] * len(body)))
    if "router" in names:
        return P(*lead, *([None] * len(body)))
    if any(n in names for n in ("gate", "up")) and "moe" in names:
        e, d, f = body
        if FLAGS["moe_expert_parallel"] and e % _axis_size(mesh, "model") == 0:
            return P(*lead, "model", _maybe(mesh, d, dp), None)
        if FLAGS["dense_pure_tp"]:
            return P(*lead, None, None, _maybe(mesh, f, "model"))
        if FLAGS["fsdp_same_dim"]:
            ax = ("model", dp) if f % _axis_size(mesh, ("model", dp)) == 0 \
                else "model"
            return P(*lead, None, None, _maybe(mesh, f, ax))
        return P(*lead, None, _maybe(mesh, d, dp), _maybe(mesh, f, "model"))
    if "down" in names and "moe" in names:
        e, f, d = body
        if FLAGS["moe_expert_parallel"] and e % _axis_size(mesh, "model") == 0:
            return P(*lead, "model", None, _maybe(mesh, d, dp))
        if FLAGS["dense_pure_tp"]:
            return P(*lead, None, _maybe(mesh, f, "model"), None)
        if FLAGS["fsdp_same_dim"]:
            ax = ("model", dp) if f % _axis_size(mesh, ("model", dp)) == 0 \
                else "model"
            return P(*lead, None, _maybe(mesh, f, ax), None)
        return P(*lead, None, _maybe(mesh, f, "model"), _maybe(mesh, d, dp))
    if "conv_w" in names:
        k, c = body
        return P(*lead, None, _maybe(mesh, c, "model"))
    if any(n in names for n in ("wo", "down", "out_proj")):
        a, b = body
        if FLAGS["dense_pure_tp"]:
            return P(*lead, _maybe(mesh, a, "model"), None)
        if FLAGS["fsdp_same_dim"]:
            ax = ("model", dp) if a % _axis_size(mesh, ("model", dp)) == 0 \
                else "model"
            return P(*lead, _maybe(mesh, a, ax), None)
        return P(*lead, _maybe(mesh, a, "model"), _maybe(mesh, b, dp))
    if len(body) == 2:
        # wq/wk/wv, ffn gate/up, ssm in_proj: (d_in, d_out)
        a, b = body
        if FLAGS["dense_pure_tp"]:
            return P(*lead, None, _maybe(mesh, b, "model"))
        if FLAGS["fsdp_same_dim"]:
            ax = ("model", dp) if b % _axis_size(mesh, ("model", dp)) == 0 \
                else "model"
            return P(*lead, None, _maybe(mesh, b, ax))
        return P(*lead, _maybe(mesh, a, dp), _maybe(mesh, b, "model"))
    return P(*lead, *([None] * len(body)))


def shard_tree(mesh, tree):
    """NamedShardings for a pytree of arrays/ShapeDtypeStructs."""
    def f(path, leaf):
        return NamedSharding(mesh, param_spec(mesh, path, leaf))
    return jax.tree_util.tree_map_with_path(f, tree)


def batch_axes(mesh):
    dp = data_axes(mesh)
    if FLAGS["batch_both"]:
        return dp + ("model",)
    return dp


def batch_spec(mesh, leaf) -> P:
    dp = batch_axes(mesh)
    if leaf.ndim == 0 or leaf.shape[0] % _axis_size(mesh, dp) != 0:
        return P(*([None] * leaf.ndim))
    return P(dp, *([None] * (leaf.ndim - 1)))


def shard_batch(mesh, batch):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, l)), batch)


def cache_spec(mesh, path, leaf, batch: int) -> P:
    """Decode-cache sharding (stacked leading reps dim on every leaf)."""
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    dp = data_axes(mesh)
    shape = leaf.shape
    if names and names[-1] in ("k", "v"):
        r, b, s, h, d = shape
        if batch > 1 and b % _axis_size(mesh, dp) == 0:
            seq_ax = _maybe(mesh, s, "model")
            return P(None, dp, seq_ax, None, None)
        seq_ax = ("data", "model") if s % _axis_size(mesh, ("data", "model")) == 0 else None
        return P(None, None, seq_ax, None, None)
    if names and names[-1] == "state":
        r, b, h, p_, n = shape
        bd = dp if (batch > 1 and b % _axis_size(mesh, dp) == 0) else None
        return P(None, bd, _maybe(mesh, h, "model"), None, None)
    if names and names[-1] == "conv":
        r, b, k, c = shape
        bd = dp if (batch > 1 and b % _axis_size(mesh, dp) == 0) else None
        return P(None, bd, None, _maybe(mesh, c, "model"))
    return P(*([None] * leaf.ndim))


def shard_caches(mesh, caches, batch: int):
    def f(path, leaf):
        return NamedSharding(mesh, cache_spec(mesh, path, leaf, batch))
    return jax.tree_util.tree_map_with_path(f, caches)


def replicated(mesh, tree):
    return jax.tree.map(lambda l: NamedSharding(mesh, P()), tree)
