"""Serving driver: batched prefill + decode against the KV/SSM caches.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.key(0)
    params = T.init_params(cfg, key)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": prompt}
    if cfg.is_enc_dec:
        batch["enc_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len // 4, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.frontend_seq, cfg.d_model)), jnp.float32)

    max_len = args.prompt_len + args.gen + (
        cfg.frontend_seq if cfg.frontend == "vision" else 0)
    prefill = jax.jit(lambda p, b: T.prefill(cfg, p, b, max_len))
    decode = jax.jit(
        lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos0=pos))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    pos = args.prompt_len + (
        cfg.frontend_seq if cfg.frontend == "vision" else 0)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out.append(np.asarray(tok)[:, 0])
        logits, caches = decode(params, tok,
                                caches, jnp.asarray(pos + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill:.2f}s; {args.gen} decode steps in {t_decode:.2f}s "
          f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0].tolist())


if __name__ == "__main__":
    main()
