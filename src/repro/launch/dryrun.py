import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without real hardware: the
512 placeholder host devices let ``jax.make_mesh`` build the production
meshes (16x16 single-pod, 2x16x16 multi-pod); ``.lower().compile()``
runs the full GSPMD partitioner, and the compiled artifact yields the
memory analysis, FLOP/byte counts and the collective schedule that feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out results/dryrun.json
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicable
from repro.launch.sharding import (shard_batch, shard_caches, shard_tree,
                                   replicated)
from repro.launch.specs import (decode_specs, opt_state_specs, params_specs,
                                train_batch_specs)
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.optim import AdamWConfig

def _msize(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\])[^=]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in compiled HLO."""
    per_kind = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"= (\(?[\w\[\],{}\s/]*?\)?) (all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
    return per_kind


MICROBATCHES = [1]


def _lower_cell(cfg, shape, mesh, opt_cfg):
    """Lower the cell's step function against ShapeDtypeStruct specs."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import data_axes
    from repro.models.transformer import activation_sharding
    from repro.launch import sharding as _sh
    dp = _sh.batch_axes(mesh)
    bdim = (dp if shape.global_batch % _msize(mesh, dp) == 0
            and shape.global_batch > 1 else None)
    mode = _sh.FLAGS["act_shard"]
    if mode == "seq" and shape.seq_len % mesh.shape["model"] == 0:
        act = P(bdim, "model")
    elif mode == "d":
        act = P(bdim, None, "model")
    else:
        act = P(bdim)
    from repro.models.transformer import moe_groups
    n_tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    g = _msize(mesh, dp) if bdim is not None and n_tokens % _msize(
        mesh, dp) == 0 else 1
    with mesh, activation_sharding(act), moe_groups(g):
        if shape.kind == "train":
            p_specs = params_specs(cfg)
            o_specs = opt_state_specs(cfg, opt_cfg)
            b_specs = train_batch_specs(cfg, shape)
            fn = make_train_step(cfg, opt_cfg, microbatches=MICROBATCHES[0])
            in_sh = (shard_tree(mesh, p_specs), shard_tree(mesh, o_specs),
                     shard_batch(mesh, b_specs))
            lowered = jax.jit(fn, in_shardings=in_sh).lower(
                p_specs, o_specs, b_specs)
        elif shape.kind == "prefill":
            p_specs = params_specs(cfg)
            b_specs = train_batch_specs(cfg, shape)
            b_specs.pop("labels")
            fn = make_prefill_step(cfg, shape.seq_len)
            in_sh = (shard_tree(mesh, p_specs), shard_batch(mesh, b_specs))
            lowered = jax.jit(fn, in_shardings=in_sh).lower(p_specs, b_specs)
        else:  # decode
            p_specs = params_specs(cfg)
            d = decode_specs(cfg, shape)
            fn = make_decode_step(cfg)
            args = [d["tokens_last"], d["caches"], d["pos0"]]
            in_sh = [shard_batch(mesh, d["tokens_last"]),
                     shard_caches(mesh, d["caches"], shape.global_batch),
                     replicated(mesh, d["pos0"])]
            if cfg.is_enc_dec:
                args += [d["enc_out"], d["enc_pos"]]
                in_sh += [shard_batch(mesh, d["enc_out"]),
                          replicated(mesh, d["enc_pos"])]
            lowered = jax.jit(
                fn, in_shardings=(shard_tree(mesh, p_specs), *in_sh)
            ).lower(p_specs, *args)

    return lowered


def _cell_cost(cfg, shape, mesh, opt_cfg):
    """(flops, bytes, collective-bytes-per-kind) per device for one step.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so scanned layer stacks are undercounted.  Since every cost is
    exactly linear in the scan length (cost = a + b*reps), we compile
    1-block and 2-block variants of the same config and extrapolate to
    the real depth.  Encoder-decoder models get a third variant to
    separate the encoder slope.
    """
    import dataclasses as _dc

    L = len(cfg.block_pattern)
    has_enc = cfg.is_enc_dec

    from repro.models.transformer import unrolled_stack

    def cost_at(m_dec: int, m_enc: int):
        c2 = _dc.replace(cfg, n_layers=L * m_dec,
                         n_enc_layers=(m_enc if has_enc else 0))
        with unrolled_stack():
            lowered = _lower_cell(c2, shape, mesh, opt_cfg)
        comp = lowered.compile()
        cost = comp.cost_analysis()
        coll = collective_bytes(comp.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    def sub(x, y):
        if isinstance(x, dict):
            keys = set(x) | set(y)
            return {k: x.get(k, 0) - y.get(k, 0) for k in keys}
        return x - y

    def lin(base, slope, n):
        if isinstance(base, dict):
            keys = set(base) | set(slope)
            return {k: max(base.get(k, 0) + slope.get(k, 0) * n, 0.0)
                    for k in keys}
        return max(base + slope * n, 0.0)

    f11 = cost_at(1, 1)
    f21 = cost_at(2, 1)
    b = tuple(sub(x, y) for x, y in zip(f21, f11))
    if has_enc:
        f12 = cost_at(1, 2)
        c = tuple(sub(x, y) for x, y in zip(f12, f11))
        a = tuple(sub(sub(x, y), z) for x, y, z in zip(f11, b, c))
        reps_enc = cfg.n_enc_layers
        out = []
        for ai, bi, ci in zip(a, b, c):
            t = lin(ai, bi, cfg.reps)
            t = lin(t, ci, reps_enc) if not isinstance(t, dict) else {
                k: max(t.get(k, 0) + ci.get(k, 0) * reps_enc, 0.0)
                for k in set(t) | set(ci)}
            out.append(t)
        return tuple(out)
    a = tuple(sub(x, y) for x, y in zip(f11, b))
    return tuple(lin(ai, bi, cfg.reps) for ai, bi in zip(a, b))


def analytic_cell(cfg, shape, chips: int, moment_bytes: int) -> dict:
    """First-principles per-device residency and HBM traffic (bytes).

    The CPU backend's HLO "bytes accessed" is fusion-blind (every op's
    operands counted at full size) and its temp accounting reflects CPU
    buffer assignment, so the fit/memory roofline terms use this analytic
    model instead; both are reported.
    """
    P_total = cfg.param_count()
    P_local = P_total / chips
    dp = max(chips // 16, 1) if shape.global_batch > 1 else 1
    b_loc = max(shape.global_batch // dp, 1)
    s = shape.seq_len
    d = cfg.d_model
    v_loc = cfg.vocab / 16 if cfg.vocab % 16 == 0 else cfg.vocab
    act_frac = cfg.active_param_count() / P_total

    if shape.kind == "train":
        resident = P_local * (2 + 2 * moment_bytes)      # params + m + v
        # saved block inputs; only one microbatch's worth is live at once
        resident += cfg.reps * b_loc * s * d * 2 / MICROBATCHES[0]
        traffic = P_local * (2 * 3 * act_frac + 2 * moment_bytes + 2)
        traffic += cfg.reps * b_loc * s * d * 2 * 2
        traffic += b_loc * s * v_loc * 4 * 2
    elif shape.kind == "prefill":
        resident = P_local * 2 + _cache_bytes(cfg, shape, chips)
        traffic = P_local * 2 * act_frac + _cache_bytes(cfg, shape, chips)
        traffic += b_loc * s * d * 2 * cfg.n_layers / 4   # block activations
    else:  # decode: one token
        cache = _cache_bytes(cfg, shape, chips)
        resident = P_local * 2 + cache
        traffic = P_local * 2 * act_frac + cache          # read whole cache
    return {"resident_bytes": float(resident), "traffic_bytes": float(traffic)}


def _cache_bytes(cfg, shape, chips: int) -> float:
    """Per-device KV/SSM cache bytes for this shape."""
    total = 0.0
    reps = cfg.reps
    for spec in cfg.block_pattern:
        if spec.kind == "ssm":
            d_inner = cfg.ssm_expand * cfg.d_model
            h = d_inner // cfg.ssm_head_dim
            total += reps * shape.global_batch * (
                h * cfg.ssm_head_dim * cfg.ssm_state * 4
                + 3 * (d_inner + 2 * cfg.ssm_state) * 2)
        else:
            alloc = shape.seq_len
            if spec.kind == "swa" and cfg.window:
                alloc = min(alloc, cfg.window)
            total += (reps * shape.global_batch * alloc
                      * cfg.n_kv_heads * cfg.hd * 2 * 2)
    return total / chips


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped (full-attention arch, long-context cell)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # jamba's 398B params need bf16 moments to fit 16GB/chip at 256 chips
    moment_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 else jnp.float32
    opt_cfg = AdamWConfig(moment_dtype=moment_dtype)

    lowered = _lower_cell(cfg, shape, mesh, opt_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    coll_full_once = collective_bytes(compiled.as_text())

    t0 = time.time()
    flops, bytes_acc, coll = _cell_cost(cfg, shape, mesh, opt_cfg)
    t_cost = time.time() - t0

    chips = 512 if multi_pod else 256
    ana = analytic_cell(cfg, shape, chips,
                        2 if moment_dtype == jnp.bfloat16 else 4)
    coll_total = float(sum(coll.values()))
    t_compute = flops / PEAK_FLOPS
    t_memory = ana["traffic_bytes"] / HBM_BW
    t_memory_hlo = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW

    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_probe_s": round(t_cost, 1),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "collectives_body_once": coll_full_once,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_hlo_s": t_memory_hlo, "t_collective_s": t_coll,
        "analytic": ana,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }
    if verbose:
        ma = res["memory_analysis"]
        print(f"  {arch} x {shape_name} x {res['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {ma['argument_bytes']/2**30:.2f}GiB "
              f"temp {ma['temp_bytes']/2**30:.2f}GiB | "
              f"resident {ana['resident_bytes']/2**30:.2f}GiB | "
              f"flops/dev {flops:.3g} bytes/dev {ana['traffic_bytes']:.3g} "
              f"coll/dev {coll_total:.3g} -> {res['bottleneck']}-bound",
              flush=True)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--set", action="append", default=[],
                    help="sharding FLAGS override, e.g. --set moe_expert_parallel=1")
    args = ap.parse_args()

    MICROBATCHES[0] = args.microbatch
    from repro.launch import sharding as _sh
    for kv in args.set:
        k, v = kv.split("=")
        assert k in _sh.FLAGS, f"unknown flag {k}"
        _sh.FLAGS[k] = v if k == "act_shard" else bool(int(v))

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp))
                except Exception as e:  # a failure here is a sharding bug
                    failures += 1
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": f"FAILED: {e}"})
                    print(f"  {arch} x {shape} FAILED: {e}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(r["status"] == "ok" for r in results)
    skipped = sum("skipped" in r["status"] for r in results)
    print(f"dry-run: {ok} ok, {skipped} skipped, {failures} FAILED")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
