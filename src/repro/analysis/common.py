"""Shared infrastructure of the static-analysis passes.

Findings, source helpers, the ``# lint:`` comment grammar and the
alpha-renaming AST normalizer used by the mirror-site pass.

Comment grammar (DESIGN.md "Static invariant analysis"):

  * ``# lint: mirror(<group>)`` — marks the statement starting on this
    line (or, on a bare comment line, the next statement) as one site of
    mirror group ``<group>``; all sites of a group must normalize to
    the same expression shape.
  * ``# lint: exempt(<check>, TOK1 TOK2 ...): reason`` — exempts the
    listed tokens from ``<check>`` (e.g. ``stats-columns`` column names,
    a sweepable-field name).  The reason is mandatory: an exemption
    without a justification is itself a finding.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

REPO_ROOT = Path(__file__).resolve().parents[3]

_MIRROR_RE = re.compile(r"#\s*lint:\s*mirror\(([\w.-]+)\)")
_EXEMPT_RE = re.compile(
    r"#\s*lint:\s*exempt\(([\w.-]+)\s*,\s*([^)]*)\)\s*(?::\s*(.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured lint finding: location, rule id, message, fix hint."""

    file: str        # repo-relative path
    line: int        # 1-based line number
    rule: str        # kebab-case rule id (stable; tests assert on it)
    message: str
    suggestion: str = ""

    def render(self) -> str:
        s = f" [{self.suggestion}]" if self.suggestion else ""
        return f"{self.file}:{self.line}: {self.rule}: {self.message}{s}"


def rel(path: "Path | str") -> str:
    """Repo-relative display path (absolute paths outside the repo are
    kept as-is — fixture corpora under a tmpdir stay addressable)."""
    p = Path(path).resolve()
    try:
        return str(p.relative_to(REPO_ROOT))
    except ValueError:
        return str(p)


def read_source(path: "Path | str") -> Tuple[str, List[str]]:
    text = Path(path).read_text()
    return text, text.splitlines()


def find_line(lines: Sequence[str], pattern: str,
              start: int = 0) -> Optional[int]:
    """1-based line number of the first line matching ``pattern``."""
    rx = re.compile(pattern)
    for i in range(start, len(lines)):
        if rx.search(lines[i]):
            return i + 1
    return None


@dataclasses.dataclass(frozen=True)
class MirrorMarker:
    group: str
    line: int          # line the marked statement starts on


@dataclasses.dataclass(frozen=True)
class Exemption:
    check: str
    tokens: Tuple[str, ...]
    reason: str
    line: int


def parse_markers(lines: Sequence[str]) -> List[MirrorMarker]:
    """Collect ``# lint: mirror(...)`` markers.

    A marker trailing code applies to the statement starting on its own
    line; a marker on a bare comment line applies to the next line.
    """
    out = []
    for i, raw in enumerate(lines):
        m = _MIRROR_RE.search(raw)
        if not m:
            continue
        code = raw[:m.start()].strip()
        target = i + 1 if code else i + 2
        out.append(MirrorMarker(group=m.group(1), line=target))
    return out


def parse_exemptions(lines: Sequence[str]) -> List[Exemption]:
    out = []
    for i, raw in enumerate(lines):
        m = _EXEMPT_RE.search(raw)
        if not m:
            continue
        tokens = tuple(t for t in m.group(2).split() if t)
        reason = (m.group(3) or "").strip()
        out.append(Exemption(check=m.group(1), tokens=tokens,
                             reason=reason, line=i + 1))
    return out


# ---------------------------------------------------------------------------
# AST statement lookup + alpha-renaming normalizer (mirror pass)
# ---------------------------------------------------------------------------

def statements_by_line(tree: ast.Module) -> Dict[int, ast.stmt]:
    """Innermost statement starting at each line (smallest span wins)."""
    at: Dict[int, ast.stmt] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.stmt):
            continue
        ln = node.lineno
        prev = at.get(ln)
        if prev is None or (_span(node) < _span(prev)):
            at[ln] = node
    return at


def _span(node: ast.stmt) -> int:
    return (getattr(node, "end_lineno", node.lineno) or node.lineno) \
        - node.lineno


def function_spans(tree: ast.Module) -> Dict[str, Tuple[int, int]]:
    """Dotted qualname -> (first line, last line) for every def."""
    spans: Dict[str, Tuple[int, int]] = {}

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}{child.name}"
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    spans[qual] = (child.lineno, child.end_lineno)
                walk(child, qual + ".")
            else:
                walk(child, prefix)

    walk(tree, "")
    return spans


def module_preserved_names(tree: ast.Module) -> set:
    """Names the normalizer must NOT alpha-rename for this module:
    imports, module-level defs/constants, and a few builtins.  ALL_CAPS
    names are additionally preserved everywhere (constants by
    convention, wherever they were defined)."""
    keep = {"int", "float", "bool", "len", "max", "min", "range", "abs"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                keep.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                keep.add(a.asname or a.name)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            keep.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    keep.add(t.id)
    return keep


def _is_const_name(name: str) -> bool:
    return len(name) > 1 and name.isupper()


class _Renamer(ast.NodeTransformer):
    """Alpha-rename local names (and attribute chains rooted at them)
    to positional placeholders in first-occurrence order.

    ``st.stats`` in the handler and ``stats_cur`` in the macro both
    collapse to one placeholder, so structurally mirrored statements
    normalize equal regardless of local naming.
    """

    def __init__(self, preserved: set, prefix: str):
        self.preserved = preserved
        self.prefix = prefix
        self.map: Dict[str, str] = {}

    def _keep(self, name: str) -> bool:
        return name in self.preserved or _is_const_name(name)

    def _placeholder(self, key: str) -> str:
        if key not in self.map:
            self.map[key] = f"{self.prefix}{len(self.map)}"
        return self.map[key]

    @staticmethod
    def _chain(node: ast.Attribute) -> Optional[List[str]]:
        parts = []
        cur: ast.expr = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if isinstance(cur, ast.Name):
            parts.append(cur.id)
            return parts[::-1]
        return None

    def visit_Attribute(self, node: ast.Attribute):
        chain = self._chain(node)
        if chain is not None and not self._keep(chain[0]):
            name = self._placeholder(".".join(chain))
            return ast.copy_location(ast.Name(id=name, ctx=ast.Load()),
                                     node)
        return self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if self._keep(node.id):
            return node
        return ast.copy_location(
            ast.Name(id=self._placeholder(node.id), ctx=node.ctx), node)


def normalize_stmt(stmt: ast.stmt, preserved: set) -> str:
    """Canonical dump of one statement under alpha-renaming.

    Assignment targets rename in their own ``_t*`` namespace so that a
    carry-style in-place update (``x = x.at[...]``) and a fresh binding
    (``y = x.at[...]``) normalize identically — the mirror contract is
    about the *computed expression*, not the binding style.
    """
    stmt = ast.parse(ast.unparse(stmt)).body[0]   # drop position noise
    values = _Renamer(preserved, "_v")
    targets = _Renamer(preserved, "_t")
    if isinstance(stmt, ast.Assign):
        stmt.value = values.visit(stmt.value)
        stmt.targets = [targets.visit(t) for t in stmt.targets]
    elif isinstance(stmt, ast.AugAssign):
        stmt.value = values.visit(stmt.value)
        stmt.target = targets.visit(stmt.target)
    elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
        stmt.value = values.visit(stmt.value)
        stmt.target = targets.visit(stmt.target)
    else:
        stmt = values.visit(stmt)
    ast.fix_missing_locations(stmt)
    return ast.dump(stmt)


def names_used(node: ast.AST, pattern: str) -> Dict[str, int]:
    """Names matching ``pattern`` loaded anywhere under ``node``:
    name -> first line seen."""
    rx = re.compile(pattern)
    out: Dict[str, int] = {}
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and rx.fullmatch(n.id):
            out.setdefault(n.id, n.lineno)
    return out


def attribute_names(trees: Iterable[ast.AST]) -> set:
    """Every attribute name accessed anywhere in the given ASTs."""
    out = set()
    for tree in trees:
        for n in ast.walk(tree):
            if isinstance(n, ast.Attribute):
                out.add(n.attr)
    return out
