"""CLI for the static invariant analysis.

    PYTHONPATH=src python -m repro.analysis [options]

Options:
    --fail-on-findings   exit 1 when any finding survives (CI mode)
    --json PATH          write a machine-readable summary (ANALYSIS.json)
    --pass NAME          run a single pass (repeatable); default: all
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.analysis import PASSES, run_pass
from repro.analysis.common import Finding


def _summary(results: Dict[str, List[Finding]]) -> dict:
    passes = {}
    for name, findings in results.items():
        rules: Dict[str, int] = {}
        for f in findings:
            rules[f.rule] = rules.get(f.rule, 0) + 1
        passes[name] = {
            "findings": len(findings),
            "rules": dict(sorted(rules.items())),
        }
    return {
        "total_findings": sum(len(v) for v in results.values()),
        "passes": passes,
        "findings": [
            {"pass": name, "file": f.file, "line": f.line,
             "rule": f.rule, "message": f.message,
             "suggestion": f.suggestion}
            for name, findings in results.items() for f in findings
        ],
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant analysis over the engine, oracle "
                    "and benchmarks")
    parser.add_argument("--fail-on-findings", action="store_true",
                        help="exit 1 when any finding survives")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the findings summary as JSON")
    parser.add_argument("--pass", dest="passes", action="append",
                        choices=sorted(PASSES), default=None,
                        help="run only this pass (repeatable)")
    args = parser.parse_args(argv)

    names = args.passes or list(PASSES)
    results: Dict[str, List[Finding]] = {}
    for name in names:
        try:
            results[name] = run_pass(name)
        except Exception as e:  # a crashed pass is itself a finding
            results[name] = [Finding(
                file="<analysis>", line=0, rule=f"{name}-pass-error",
                message=f"pass crashed: {type(e).__name__}: {e}",
                suggestion="fix the pass (repro/analysis) or the "
                           "contract it traces")]

    total = 0
    for name in names:
        findings = results[name]
        total += len(findings)
        status = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"analysis: {name}: {status}")
        for f in findings:
            print(f"  {f.render()}")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(_summary(results), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"analysis: wrote {args.json}")

    if total:
        print(f"analysis: {total} finding(s) across "
              f"{sum(1 for n in names if results[n])} pass(es)",
              file=sys.stderr)
        return 1 if args.fail_on_findings else 0
    print(f"analysis: all {len(names)} pass(es) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
