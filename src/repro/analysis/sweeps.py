"""Pass 5 (satellite): sweep-telemetry registry vs. emitted keys.

``benchmarks/_sweeps.py`` is the single source of truth for the sweep
base names; ``check_compiles`` derives GUARDED / MACRO_KEYS from it at
import time.  What nothing else pins is the *emission* side: a figure
script that records ``newthing_sweep_compiles`` without registering the
sweep would sail through ``check_compiles`` unguarded, and a registered
sweep whose figure script was retired would fail the bench lane only
after a full run.  This pass AST-parses both sides and diffs them:

  * ``sweep-unregistered`` — a ``sweep_metrics.update(...)`` site emits
    a base name missing from the registry;
  * ``sweep-stale`` — the registry names a sweep no script emits;
  * ``sweep-missing-key`` — a sweep emits only some of the five
    required suffixes (wall_s / compile_s / compiles / cells /
    macro_hit).

``_shared.py`` is the one special case: it records ``grid_*`` into
``grid_metrics`` and ``run.py`` re-prefixes those to ``shared_grid_*``,
so ``grid_metrics.update`` sites count as the ``shared_grid`` sweep.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.common import Finding, rel, REPO_ROOT

_BENCH = REPO_ROOT / "benchmarks"
_REGISTRY = "_sweeps.py"
_SUFFIXES = ("wall_s", "compile_s", "compiles", "cells", "macro_hit")


def _registered(bench_dir: Path) -> Tuple[Dict[str, int], int]:
    """SWEEPS entries of the registry module -> line, plus the tuple's
    own line for stale-anchor fallback."""
    path = bench_dir / _REGISTRY
    tree = ast.parse(path.read_text())
    out: Dict[str, int] = {}
    reg_line = 1
    for node in tree.body:
        targets = node.targets if isinstance(node, ast.Assign) else \
            [node.target] if isinstance(node, ast.AnnAssign) else []
        if not any(isinstance(t, ast.Name) and t.id == "SWEEPS"
                   for t in targets):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            reg_line = node.lineno
            for e in node.value.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value,
                                                              str):
                    out[e.value] = e.lineno
    return out, reg_line


def _emitted(bench_dir: Path
             ) -> Dict[str, Tuple[str, int, Set[str]]]:
    """base -> (file, line, suffixes emitted) over all update sites."""
    out: Dict[str, Tuple[str, int, Set[str]]] = {}
    for path in sorted(bench_dir.glob("*.py")):
        if path.name == _REGISTRY:
            continue
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "update"
                    and isinstance(node.func.value, ast.Name)):
                continue
            recv = node.func.value.id
            if recv not in ("sweep_metrics", "grid_metrics"):
                continue
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                for suffix in _SUFFIXES:
                    if not kw.arg.endswith(f"_{suffix}"):
                        continue
                    base = kw.arg[:-len(suffix) - 1]
                    if recv == "grid_metrics":
                        # run.py re-prefixes grid_metrics keys with
                        # "shared_" before they reach the report
                        base = f"shared_{base}"
                    file, line, seen = out.get(
                        base, (rel(path), node.lineno, set()))
                    seen.add(suffix)
                    out[base] = (file, line, seen)
                    break
    return out


def check(bench_dir: Optional[Path] = None) -> List[Finding]:
    bench_dir = _BENCH if bench_dir is None else bench_dir
    registered, reg_line = _registered(bench_dir)
    emitted = _emitted(bench_dir)
    reg_file = rel(bench_dir / _REGISTRY)
    findings: List[Finding] = []
    for base, (file, line, seen) in sorted(emitted.items()):
        if base not in registered:
            findings.append(Finding(
                file=file, line=line, rule="sweep-unregistered",
                message=f"sweep {base!r} emits telemetry but is not in "
                        f"the {_REGISTRY} SWEEPS registry, so "
                        "check_compiles never guards its compile count",
                suggestion=f"add {base!r} to SWEEPS in "
                           f"benchmarks/{_REGISTRY}"))
            continue
        missing = [s for s in _SUFFIXES if s not in seen]
        if missing:
            findings.append(Finding(
                file=file, line=line, rule="sweep-missing-key",
                message=f"sweep {base!r} never emits required key(s) "
                        f"{', '.join(f'{base}_{s}' for s in missing)}",
                suggestion="record the missing telemetry in the sweep's "
                           "sweep_metrics.update(...) call"))
    for base, line in sorted(registered.items()):
        if base not in emitted:
            findings.append(Finding(
                file=reg_file, line=line or reg_line, rule="sweep-stale",
                message=f"registered sweep {base!r} has no "
                        "sweep_metrics.update emission site in "
                        "benchmarks/",
                suggestion="remove the stale registry entry or restore "
                           "the sweep's telemetry"))
    return findings
