"""Static invariant analysis for the persistent-CXL-switch simulator.

Five passes, each pinning a contract the test suite can only probe
dynamically (and expensively):

  * ``retrace``  — every sweepable config knob survives DCE of the
    abstractly traced engine cell (no baked statics);
  * ``mirror``   — replicated engine expressions (slot/NoPB/macro
    twins, policy guards) stay structurally identical, and handler
    families cover the same stats columns;
  * ``twin``     — engine and untimed oracle consume the same policy
    fields and map their statistics onto each other;
  * ``dtypes``   — the packed scan carry keeps its dtypes, no f64->f32
    time leaks, the grid donates its staged buffers;
  * ``sweeps``   — the benchmark sweep registry matches the telemetry
    the figure scripts actually emit.

CLI: ``python -m repro.analysis [--fail-on-findings] [--json PATH]``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.common import Finding

__all__ = ["Finding", "PASSES", "run_all", "run_pass"]


def _retrace() -> List[Finding]:
    from repro.analysis import retrace
    return retrace.check_engine()


def _mirror() -> List[Finding]:
    from repro.analysis import mirror
    return mirror.check()


def _twin() -> List[Finding]:
    from repro.analysis import twin
    return twin.check()


def _dtypes() -> List[Finding]:
    from repro.analysis import dtypes
    return dtypes.check()


def _sweeps() -> List[Finding]:
    from repro.analysis import sweeps
    return sweeps.check()


PASSES = {
    "retrace": _retrace,
    "mirror": _mirror,
    "twin": _twin,
    "dtypes": _dtypes,
    "sweeps": _sweeps,
}


def run_pass(name: str) -> List[Finding]:
    return PASSES[name]()


def run_all() -> Dict[str, List[Finding]]:
    """Run every pass; pass name -> findings (empty list when clean)."""
    return {name: fn() for name, fn in PASSES.items()}
