"""Pass 2: AST mirror-site lint over the engine's replicated expressions.

The engine computes several load-bearing expressions at more than one
site — the slot-at-a-time persist handler, the NoPB handler and the
macro-step mini-interpreter must stay *bit-exact* twins (the crash
differential and the macro on/off diff depend on it), and the macro
guard replicates sub-expressions of ``policy.drain_threshold_preset``.
A one-character skew at any site silently breaks bit-exactness in ways
only the expensive differential suites catch.

Sites register with a ``# lint: mirror(<group>)`` comment on (or right
above) the statement.  All sites of a group are alpha-renamed
(``common.normalize_stmt``) and diffed pairwise: local names collapse
to positional placeholders, so ``st.stats[...]`` in the handler and
``stats_cur[...]`` in the macro compare structurally.  The registry
below pins the expected site count per group — deleting a marked site
(or its marker) is itself a finding.

The second check is column coverage: every ``S_*`` stats column
referenced by one handler family must be referenced by the others or
explicitly exempted with ``# lint: exempt(stats-columns, S_X ...):
reason`` inside one of the family's functions.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import (Finding, module_preserved_names,
                                   normalize_stmt, parse_exemptions,
                                   parse_markers, read_source, rel,
                                   statements_by_line, function_spans,
                                   names_used, REPO_ROOT)

_ENGINE = REPO_ROOT / "src" / "repro" / "core" / "engine"

# group -> expected site count across the engine sources.  The counts
# are part of the contract: N sites must exist AND normalize equal.
MIRROR_GROUPS: Dict[str, int] = {
    "lat-bin": 3,        # buffered / NoPB / macro histogram column
    "slo-over": 4,       # over-target predicate (buffered, NoPB, macro x2)
    "slo-cnt": 2,        # running persist count incl. this persist
    "slo-run": 2,        # running over-target count incl. this persist
    "slo-tight": 2,      # tightening predicate
    "rf-tight-thr": 2,   # tight threshold override (policy vs macro guard)
    "rf-tight-pre": 2,   # tight preset override
    "rf-do-drain": 2,    # threshold trigger (policy vs macro guard)
    "rf-k-thresh": 2,    # threshold/preset drain count
    "rf-k-low": 2,       # keep-one-free drain count
    "stats-scatter": 3,  # fused per-op stats scatter-add
}

_MIRROR_FILES = ("handlers.py", "macro.py", "policy.py")

# Handler families for the column-coverage check: qualnames whose S_*
# references are pooled per family.
FAMILIES: Dict[str, List[Tuple[str, str]]] = {
    "buffered": [("handlers.py", "_persist_with_buffer"),
                 ("handlers.py", "handle_pm_read.via_pb")],
    "nopb": [("handlers.py", "handle_persist.nopb"),
             ("handlers.py", "handle_pm_read.direct")],
    "macro": [("macro.py", "macro_step.win_op")],
}


def check_mirrors(paths: Optional[Sequence[Path]] = None,
                  expected: Optional[Dict[str, int]] = None
                  ) -> List[Finding]:
    """Collect all marked sites and diff each group pairwise."""
    if paths is None:
        paths = [_ENGINE / f for f in _MIRROR_FILES]
        expected = MIRROR_GROUPS if expected is None else expected
    findings: List[Finding] = []
    # group -> [(file, line, normalized dump, raw source)]
    sites: Dict[str, List[Tuple[str, int, str, str]]] = {}
    for path in paths:
        text, lines = read_source(path)
        tree = ast.parse(text)
        preserved = module_preserved_names(tree)
        stmts = statements_by_line(tree)
        for marker in parse_markers(lines):
            stmt = stmts.get(marker.line)
            if stmt is None:
                findings.append(Finding(
                    file=rel(path), line=marker.line,
                    rule="mirror-dangling-marker",
                    message=(f"mirror({marker.group}) marker does not "
                             "attach to a statement"),
                    suggestion="put the marker on the statement's first "
                               "line or the line above it"))
                continue
            if expected is not None and marker.group not in expected:
                findings.append(Finding(
                    file=rel(path), line=marker.line,
                    rule="mirror-unknown-group",
                    message=(f"mirror group {marker.group!r} is not in "
                             "the MIRROR_GROUPS registry"),
                    suggestion="register the group with its expected "
                               "site count in repro.analysis.mirror"))
                continue
            sites.setdefault(marker.group, []).append(
                (rel(path), marker.line,
                 normalize_stmt(stmt, preserved),
                 ast.unparse(stmt)))

    for group, count in (expected or {}).items():
        got = sites.get(group, [])
        if len(got) != count:
            file, line = (got[0][:2] if got
                          else (rel(paths[0]), 1))
            findings.append(Finding(
                file=file, line=line, rule="mirror-missing-site",
                message=(f"mirror group {group!r} has {len(got)} marked "
                         f"site(s); the registry requires {count}"),
                suggestion="mark the missing site(s) with "
                           f"`# lint: mirror({group})` or update the "
                           "registry"))
    for group, group_sites in sites.items():
        if len(group_sites) < 2:
            continue
        ref_file, ref_line, ref_norm, ref_src = group_sites[0]
        for file, line, norm, src in group_sites[1:]:
            if norm != ref_norm:
                findings.append(Finding(
                    file=file, line=line, rule="mirror-skew",
                    message=(f"mirror group {group!r} site diverges "
                             f"from {ref_file}:{ref_line}: "
                             f"`{src}` vs `{ref_src}`"),
                    suggestion="make the expression structurally "
                               "identical to the reference site"))
    return findings


def check_column_coverage(
        families: Optional[Dict[str, List[Tuple[str, str]]]] = None,
        base: Optional[Path] = None) -> List[Finding]:
    """Every S_* column one family references must be referenced (or
    exempted) by every other family."""
    families = FAMILIES if families is None else families
    base = _ENGINE if base is None else base
    findings: List[Finding] = []
    used: Dict[str, Dict[str, int]] = {}     # family -> {col: line}
    exempt: Dict[str, Dict[str, str]] = {}   # family -> {col: reason}
    anchor: Dict[str, Tuple[str, int]] = {}
    for family, funcs in families.items():
        used[family] = {}
        exempt[family] = {}
        for fname, qual in funcs:
            path = base / fname
            text, lines = read_source(path)
            tree = ast.parse(text)
            spans = function_spans(tree)
            if qual not in spans:
                findings.append(Finding(
                    file=rel(path), line=1, rule="mirror-missing-site",
                    message=f"column-coverage family {family!r} names "
                            f"unknown function {qual!r}",
                    suggestion="update FAMILIES in "
                               "repro.analysis.mirror"))
                continue
            lo, hi = spans[qual]
            anchor.setdefault(family, (rel(path), lo))
            for node in ast.walk(tree):
                if (isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                        and node.lineno == lo):
                    for col, line in names_used(
                            node, r"S_[A-Z0-9_]+").items():
                        used[family].setdefault(col, line)
            for ex in parse_exemptions(lines):
                if ex.check != "stats-columns" or not lo <= ex.line <= hi:
                    continue
                if not ex.reason:
                    findings.append(Finding(
                        file=rel(path), line=ex.line,
                        rule="mirror-missing-column",
                        message="stats-columns exemption without a "
                                "reason",
                        suggestion="append `: why` to the exempt "
                                   "comment"))
                    continue
                for col in ex.tokens:
                    exempt[family][col] = ex.reason

    union = set()
    for cols in used.values():
        union |= set(cols)
    for family in families:
        missing = sorted(union - set(used[family])
                         - set(exempt[family]))
        if not missing:
            continue
        file, line = anchor.get(family, ("<unknown>", 1))
        findings.append(Finding(
            file=file, line=line, rule="mirror-missing-column",
            message=(f"handler family {family!r} never touches stats "
                     f"column(s) {', '.join(missing)} written by a "
                     "sibling family"),
            suggestion="accumulate the column(s) or exempt them with "
                       "`# lint: exempt(stats-columns, ...): reason` "
                       "inside the family"))
    return findings


def check() -> List[Finding]:
    return check_mirrors() + check_column_coverage()
