"""Pass 3: oracle-twin contract checker (engine <-> semantics parity).

The paper's correctness argument rests on the timed engine and the
untimed oracle (``core.semantics``) consuming the *same* policy and
producing *matching* statistics (the crash differential pins the
values; this pass pins the contracts statically):

  * every ``DrainPolicy`` / ``AllocPolicy`` field must be consumed on
    BOTH sides — an engine-only field silently no-ops in the oracle
    (the differential then "passes" without testing it), an oracle-only
    field silently no-ops in the engine;
  * every ``S_*`` stats column must map to its oracle ``stats`` twin
    (the S_TWINS registry) or carry an explicit exemption with a
    reason, and vice versa for the oracle's keys;
  * every ``SimResult`` field must be consumed somewhere outside its
    defining module — a result field nobody reads is a contract nobody
    checks.

Field consumption is attribute-based and *transitive through
``core.params``*: the engine consumes ``DrainPolicy.threshold`` via
``tenant_drain_counts`` (a params helper called from the lowering), so
params functions reachable from each side's sources count toward that
side.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import (Finding, attribute_names, find_line,
                                   read_source, rel, REPO_ROOT)

_SRC = REPO_ROOT / "src"
_ENGINE_DIR = _SRC / "repro" / "core" / "engine"
_SEMANTICS = _SRC / "repro" / "core" / "semantics.py"
_PARAMS = _SRC / "repro" / "core" / "params.py"
_STATE = _ENGINE_DIR / "state.py"

# S_* column -> the oracle stats key(s) it must agree with.  A column
# maps to several keys when the oracle splits it (S_READ_CNT is the
# oracle's hits + misses).
S_TWINS: Dict[str, Tuple[str, ...]] = {
    "S_PERSIST_CNT": ("persists",),
    "S_COALESCES": ("coalesces",),
    "S_READ_HITS": ("read_hits",),
    "S_READ_CNT": ("read_hits", "read_misses"),
    "S_PM_WRITES": ("pm_writes",),
    "S_STALL_TIME": ("stalls",),
    "S_SLO_OVER": ("slo_over",),
    "S_ACKED": ("acks",),
}

# Timing-only / engine-only columns with no meaningful untimed twin.
S_EXEMPT: Dict[str, str] = {
    "S_PERSIST_SUM": "latency sum; the untimed oracle has no clock",
    "S_READ_SUM": "latency sum; the untimed oracle has no clock",
    "S_PBCQ_SUM": "PBC queueing wait; timing-only",
    "S_LAT_HIST0": "latency histogram base; timing-only (mass is pinned "
                   "to S_PERSIST_CNT by the differential)",
    "S_DRAM_READS": "volatile traffic never reaches the switch/oracle",
    "S_PI_DETOURS": "PI-buffer routing artifact of the timed path",
    "S_VICTIM_CNT": "oracle twin is its STALLED event count "
                    "(victim_drains in the differential driver)",
    "S_DURABLE": "oracle twin is snapshot_durable(), not a counter",
}

# Oracle stats keys that deliberately have no S_* column.
ORACLE_EXEMPT: Dict[str, str] = {
    "drains": "hop-1 drain emissions; the engine's S_PM_WRITES counts "
              "device arrivals instead (deep hops retain/coalesce)",
}

# SimResult fields that only exist as constructor plumbing.
SIMRESULT_EXEMPT: Dict[str, str] = {}


def _parse(paths: Sequence[Path]) -> List[ast.Module]:
    return [ast.parse(Path(p).read_text()) for p in paths]


def _params_defs() -> Dict[str, ast.AST]:
    """Top-level functions AND methods of core.params by bare name."""
    tree = ast.parse(_PARAMS.read_text())
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    return defs


def _called_names(trees: Sequence[ast.AST]) -> Set[str]:
    out: Set[str] = set()
    for tree in trees:
        for n in ast.walk(tree):
            if isinstance(n, ast.Name):
                out.add(n.id)
            elif isinstance(n, ast.Attribute):
                out.add(n.attr)
    return out


def side_attribute_names(paths: Sequence[Path]) -> Set[str]:
    """Attribute names consumed by one side, expanded transitively
    through the ``core.params`` helpers the side reaches."""
    trees = _parse(paths)
    defs = _params_defs()
    included: List[ast.AST] = []
    frontier = _called_names(trees)
    seen: Set[str] = set()
    while True:
        new = [n for n in frontier if n in defs and n not in seen]
        if not new:
            break
        for n in new:
            seen.add(n)
            included.append(defs[n])
        frontier = _called_names([defs[n] for n in new])
    return attribute_names(trees + included)


def check_policy_fields(
        engine_paths: Optional[Sequence[Path]] = None,
        oracle_paths: Optional[Sequence[Path]] = None,
        fields: Optional[Dict[str, Tuple[str, int]]] = None
        ) -> List[Finding]:
    """Every policy field must be an attribute access on both sides."""
    import dataclasses

    from repro.core import params

    if engine_paths is None:
        engine_paths = sorted(_ENGINE_DIR.glob("*.py"))
    if oracle_paths is None:
        oracle_paths = [_SEMANTICS]
    if fields is None:
        fields = {}
        # Schedule rides with the policies: both sides must consume its
        # boundary vector (engine: the epoch_bounds lowering via
        # PCSConfig.epoch_boundaries; oracle: epoch_at) AND its values
        # (both through params.resolve_epoch / epoch_value)
        for cls in (params.DrainPolicy, params.AllocPolicy,
                    params.Schedule):
            _, lines = read_source(_PARAMS)
            for f in dataclasses.fields(cls):
                line = find_line(lines, rf"^\s*{f.name}\s*[:=]") or 1
                fields[f"{cls.__name__}.{f.name}"] = (rel(_PARAMS), line)

    engine_attrs = side_attribute_names(engine_paths)
    oracle_attrs = side_attribute_names(oracle_paths)
    findings = []
    for qual, (file, line) in fields.items():
        name = qual.split(".")[-1]
        if name not in engine_attrs:
            findings.append(Finding(
                file=file, line=line, rule="twin-policy-engine",
                message=f"policy field {qual} is never consumed by the "
                        "timed engine (engine/ + reachable params "
                        "helpers)",
                suggestion="lower and consume the field in the engine, "
                           "or remove it"))
        if name not in oracle_attrs:
            findings.append(Finding(
                file=file, line=line, rule="twin-policy-oracle",
                message=f"policy field {qual} is never consumed by the "
                        "untimed oracle (semantics.py + reachable "
                        "params helpers)",
                suggestion="implement the field in "
                           "semantics.PersistentBuffer, or remove it"))
    return findings


def _engine_stat_columns() -> Dict[str, int]:
    """S_* constants defined in engine/state.py -> line."""
    _, lines = read_source(_STATE)
    out = {}
    for i, raw in enumerate(lines):
        m = re.match(r"^(S_[A-Z0-9_]+)\s*=", raw)
        if m:
            out[m.group(1)] = i + 1
    return out


def _oracle_stat_keys() -> Dict[str, int]:
    """Keys of the oracle's ``self.stats`` dict literal -> line."""
    tree = ast.parse(_SEMANTICS.read_text())
    out: Dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Attribute) and t.attr == "stats"
                   for t in node.targets):
            continue
        if isinstance(node.value, ast.Dict):
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value,
                                                              str):
                    out[k.value] = k.lineno
    return out


def check_stat_twins() -> List[Finding]:
    findings: List[Finding] = []
    columns = _engine_stat_columns()
    oracle = _oracle_stat_keys()
    state_file = rel(_STATE)
    sem_file = rel(_SEMANTICS)
    for col, line in columns.items():
        if col in S_TWINS:
            for key in S_TWINS[col]:
                if key not in oracle:
                    findings.append(Finding(
                        file=state_file, line=line,
                        rule="twin-stat-missing-oracle",
                        message=f"{col} maps to oracle stats key "
                                f"{key!r}, which semantics.py does not "
                                "define",
                        suggestion="add the key to the oracle stats "
                                   "dict or fix S_TWINS"))
        elif col not in S_EXEMPT:
            findings.append(Finding(
                file=state_file, line=line, rule="twin-stat-unmapped",
                message=f"stats column {col} has no oracle twin in "
                        "S_TWINS and no exemption in S_EXEMPT",
                suggestion="map it to an oracle stats key or exempt it "
                           "with a reason in repro.analysis.twin"))
    mapped = {k for keys in S_TWINS.values() for k in keys}
    for key, line in oracle.items():
        if key not in mapped and key not in ORACLE_EXEMPT:
            findings.append(Finding(
                file=sem_file, line=line,
                rule="twin-oracle-stat-unmapped",
                message=f"oracle stats key {key!r} has no S_* twin in "
                        "S_TWINS and no exemption in ORACLE_EXEMPT",
                suggestion="map an engine column to it or exempt it "
                           "with a reason in repro.analysis.twin"))
    return findings


def check_simresult_consumed() -> List[Finding]:
    """Every SimResult field must occur outside its defining module."""
    import dataclasses

    from repro.core.engine.state import SimResult

    corpus = []
    for root in (_SRC, REPO_ROOT / "tests", REPO_ROOT / "benchmarks"):
        corpus += [p for p in root.rglob("*.py")
                   if p != _STATE and "analysis" not in p.parts]
    text = "\n".join(p.read_text() for p in corpus)
    _, state_lines = read_source(_STATE)
    findings = []
    for f in dataclasses.fields(SimResult):
        if f.name in SIMRESULT_EXEMPT:
            continue
        if not re.search(rf"\b{f.name}\b", text):
            line = find_line(state_lines, rf"^\s*{f.name}\s*[:=]") or 1
            findings.append(Finding(
                file=rel(_STATE), line=line,
                rule="twin-simresult-unconsumed",
                message=f"SimResult.{f.name} is never referenced "
                        "outside engine/state.py",
                suggestion="consume it in a test/benchmark or drop the "
                           "field"))
    return findings


def check() -> List[Finding]:
    return (check_policy_fields() + check_stat_twins()
            + check_simresult_consumed())
