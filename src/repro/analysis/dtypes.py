"""Pass 4: dtype-packing lint over the step jaxpr and the grid wrappers.

The scan carry is deliberately packed (DESIGN.md "Macro-stepping &
state packing"): categorical columns in int8, barrier counts in int16,
time columns pinned to float64.  Three silent regressions this pass
catches statically:

  * **packed-column widening** — an init or handler change that
    promotes ``state``/``owner``/... to int32 quietly triples the scan
    carry (the packing registry below is the contract; the check runs
    ``jax.eval_shape`` over a full cell so a widened carry column is
    caught wherever it happens);
  * **float64 -> float32 demotion on a time path** — the engine
    subtracts ns-scale quantities from ~1e9-scale clocks; any f64->f32
    ``convert_element_type`` in the traced program quantizes at ~100 ns
    and breaks the bit-exact differentials (the single legitimate
    narrow direction, the f32 *input* gaps widening to f64, is f32->f64
    and does not match);
  * **un-donated grid buffers** — the jitted grid wrappers must donate
    the freshly-staged trace buffers (``ops``/``addrs``/``gaps``/
    ``mlen``) so XLA reuses them for the carry instead of allocating.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.common import (Finding, find_line, read_source, rel,
                                   REPO_ROOT)

_STATE = REPO_ROOT / "src" / "repro" / "core" / "engine" / "state.py"
_GRID = REPO_ROOT / "src" / "repro" / "core" / "engine" / "grid.py"

# The packing contract: MachineState column -> dtype it must keep
# through a full cell run.  Mirrors the docstring table in
# engine.state.MachineState — this registry is the machine-checked
# form.
EXPECTED_DTYPES: Dict[str, str] = {
    "clock": "float64", "ptr": "int32",
    "tag": "int32", "state": "int8", "lru": "float64", "dd": "float64",
    "ver": "int32", "owner": "int8",
    "aver": "int32", "pm_ver": "int32",
    "pm_busy": "float64", "pbc_busy": "float64",
    "blocked": "bool", "bcount": "int16",
    "stats": "float64",
    "dtag": "int32", "dstate": "int8", "dlru": "float64",
    "ddd": "float64", "dver": "int32", "downer": "int8",
    "dwt": "float64", "hpbc": "float64", "hop_stats": "float64",
    "lpbc": "float64",
}

REQUIRED_DONATED = ("ops", "addrs", "gaps", "mlen")


def check_packing(shapes: Optional[Dict[str, Tuple[str, tuple]]] = None,
                  expected: Optional[Dict[str, str]] = None,
                  anchor_file: Optional[Path] = None) -> List[Finding]:
    """Diff actual carry dtypes against the packing registry."""
    if shapes is None:
        from repro.analysis._engine import final_state_shapes
        shapes = final_state_shapes()
    expected = EXPECTED_DTYPES if expected is None else expected
    anchor_file = _STATE if anchor_file is None else anchor_file
    _, lines = read_source(anchor_file)
    findings = []
    for col, want in expected.items():
        got = shapes.get(col)
        line = find_line(lines, rf"^\s*{col}\s*[:=]") or 1
        if got is None:
            findings.append(Finding(
                file=rel(anchor_file), line=line, rule="dtype-packing",
                message=f"carry column {col!r} is registered but absent "
                        "from the traced state",
                suggestion="update EXPECTED_DTYPES in "
                           "repro.analysis.dtypes"))
            continue
        if got[0] != want:
            findings.append(Finding(
                file=rel(anchor_file), line=line, rule="dtype-packing",
                message=f"carry column {col!r} is {got[0]} after a full "
                        f"cell run; the packing contract pins {want}",
                suggestion="keep literal compares/selects weakly typed "
                           "so the packed dtype survives the handlers"))
    for col in sorted(set(shapes) - set(expected)):
        line = find_line(lines, rf"^\s*{col}\s*[:=]") or 1
        findings.append(Finding(
            file=rel(anchor_file), line=line, rule="dtype-packing",
            message=f"carry column {col!r} is not in the packing "
                    "registry",
            suggestion="register its dtype in EXPECTED_DTYPES "
                       "(repro.analysis.dtypes)"))
    return findings


def _walk_eqns(jaxpr):
    """Yield every eqn of a jaxpr, recursing into sub-jaxprs (scan,
    while, cond, pjit, ...)."""
    from jax import core as jcore
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v, jcore):
                yield from _walk_eqns(sub)


def _sub_jaxprs(v, jcore):
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _sub_jaxprs(x, jcore)


def check_f32_leaks(closed=None, fn=None, args: tuple = ()
                    ) -> List[Finding]:
    """Any f64 -> f32 ``convert_element_type`` is a time-column leak."""
    import numpy as np

    if closed is None and fn is not None:
        import jax
        from jax.experimental import enable_x64
        with enable_x64():
            closed = jax.make_jaxpr(fn)(*args)
    if closed is None:
        from repro.analysis._engine import trace_engine
        closed, _ = trace_engine(return_state=False)
    findings = []
    for eqn in _walk_eqns(closed.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new = np.dtype(eqn.params.get("new_dtype"))
        old = eqn.invars[0].aval.dtype
        if old == np.float64 and new == np.float32:
            file, line = _eqn_location(eqn)
            findings.append(Finding(
                file=file, line=line, rule="dtype-f32-leak",
                message="float64 value demoted to float32 in the traced "
                        "step: time columns quantize at ~100 ns at "
                        "clock scale",
                suggestion="keep time arithmetic in f64 (widen the f32 "
                           "operand instead)"))
    return findings


def _eqn_location(eqn) -> Tuple[str, int]:
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return rel(frame.file_name), frame.start_line
    except Exception:
        pass
    return "<traced>", 0


def check_donation(path: Optional[Path] = None,
                   required: tuple = REQUIRED_DONATED) -> List[Finding]:
    """The grid's donation tuple must cover the staged trace buffers and
    every jitted wrapper must pass it."""
    path = _GRID if path is None else path
    text, lines = read_source(path)
    tree = ast.parse(text)
    findings = []
    donated: set = set()
    donated_line = 1
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "_DONATED"
                        for t in node.targets)
                and isinstance(node.value, (ast.Tuple, ast.List))):
            donated = {e.value for e in node.value.elts
                       if isinstance(e, ast.Constant)}
            donated_line = node.lineno
    missing = sorted(set(required) - donated)
    if missing:
        findings.append(Finding(
            file=rel(path), line=donated_line, rule="dtype-undonated",
            message=f"_DONATED misses staged buffer(s) "
                    f"{', '.join(missing)}: XLA re-allocates instead of "
                    "reusing them for the scan carry",
            suggestion="add the buffer name(s) to _DONATED"))
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if not (isinstance(dec, ast.Call) and _is_jit_partial(dec)):
                continue
            kwargs = {kw.arg for kw in dec.keywords}
            if "donate_argnames" not in kwargs:
                findings.append(Finding(
                    file=rel(path), line=dec.lineno,
                    rule="dtype-undonated",
                    message=f"jitted wrapper {node.name} does not "
                            "donate its input buffers",
                    suggestion="pass donate_argnames=_DONATED to the "
                               "jit partial"))
    return findings


def _is_jit_partial(call: ast.Call) -> bool:
    """Matches ``functools.partial(jax.jit, ...)`` / ``partial(jit,
    ...)`` decorator calls."""
    f = call.func
    is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") \
        or (isinstance(f, ast.Name) and f.id == "partial")
    if not is_partial or not call.args:
        return False
    a0 = call.args[0]
    return (isinstance(a0, ast.Attribute) and a0.attr == "jit") \
        or (isinstance(a0, ast.Name) and a0.id == "jit")


def check() -> List[Finding]:
    return check_packing() + check_f32_leaks() + check_donation()
