"""Pass 1: retrace-hazard lint over the abstractly traced engine cell.

The engine's one-XLA-program sweep property holds iff every sweepable
config knob reaches the compiled program as a *traced operand*.  The
classic regression — "someone turned a traced scalar back into a
static" — replaces ``sc["x"]`` with a baked Python constant; results
stay right for the traced value but every distinct config now
recompiles, and ``check_compiles`` only notices after a full bench run.

This pass catches it in seconds: trace ``scan_cell`` with
``jax.make_jaxpr``, dead-code-eliminate the jaxpr against all outputs
(``dce_jaxpr`` recurses through scan/while/cond), and require every
lowered scalar's input var to survive — an unused invar means the
program's results provably do not depend on that operand, i.e. the knob
was baked or dropped.

It also pins the declaration side: every ``PCSConfig`` / ``DrainPolicy``
/ ``AllocPolicy`` dataclass field must be registered here as sweepable
(mapping to the ``sc`` keys it lowers to) or explicitly static (with a
reason), and every registered key must actually be emitted by
``scalars_from_config`` — so adding a policy field without lowering it,
or lowering a key without consuming it, both fail ``make lint``.
"""
from __future__ import annotations

import ast
import inspect
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import Finding, find_line, rel

# Sweepable fields: dataclass field -> the sc keys its value feeds.
# Registering a field here is the "declared sweepable" contract of
# ISSUE 8 / DESIGN.md — the keys must exist in scalars_from_config's
# output AND survive DCE of the traced cell.
SWEEPABLE_FIELDS: Dict[str, Tuple[str, ...]] = {
    "PCSConfig.crash_at_ns": ("crash_at",),
    "PCSConfig.n_tenants": ("n_tenants",),
    "PCSConfig.n_switches": ("n_switches", "ow_cpu_pm", "ow_cpu_sw1",
                             "ow_sw1_pm"),
    "PCSConfig.n_pbe": ("n_pbe", "threshold_count", "preset_count",
                        "tag_ns", "data_ns"),
    "PCSConfig.pbe_per_hop": ("deep_pbe", "deep_thr", "deep_pre",
                              "deep_tag", "deep_data"),
    "PCSConfig.drain_threshold": ("threshold_count", "t_threshold"),
    "PCSConfig.drain_preset": ("preset_count", "t_preset"),
    "DrainPolicy.threshold": ("threshold_count", "t_threshold",
                              "deep_thr"),
    "DrainPolicy.preset": ("preset_count", "t_preset", "deep_pre"),
    "DrainPolicy.per_tenant": ("drain_scope", "t_threshold", "t_preset"),
    "DrainPolicy.low_water_drains": ("low_water",),
    "DrainPolicy.empty_slack": ("empty_slack",),
    "DrainPolicy.latency_target_ns": ("lat_target",),
    "DrainPolicy.latency_tol": ("lat_tol",),
    "AllocPolicy.victim": ("victim_weighted",),
    "AllocPolicy.tenant_quota": ("quota", "share", "t_threshold",
                                 "t_preset"),
    # fan-out fabric descriptor: lowers to the leaf-partition operands
    # (engine.fabric) plus the spine backpressure watermark; a fabric
    # also forces pbe_per_hop, so the deep_* keys co-vary via that field
    "PCSConfig.fabric": ("n_leaves", "leaf_of_t", "leaf_base", "bp_high"),
    # epoched schedules (params.Schedule): the shared boundary vector
    # lowers to the one epoch_bounds operand; each epoch's values lower
    # through the wrapped knob into the EPOCH_KEYS rows the per-op
    # selection (engine.step.resolve_epoch_sc) indexes.  The exemplar
    # cell is 2-epoch, so DCE proves both the boundary vector and the
    # stacked rows stay live.
    "Schedule.boundaries_ns": ("epoch_bounds",),
    "Schedule.values": ("threshold_count", "preset_count", "quota",
                        "share", "t_threshold", "t_preset", "deep_thr",
                        "deep_pre", "lat_target", "leaf_of_t"),
}

# Statically-shaped / composite fields: changing one legitimately
# recompiles (array shapes) or lowers through child fields.
STATIC_FIELDS: Dict[str, str] = {
    "PCSConfig.scheme": "traced separately as the scheme operand",
    "PCSConfig.n_cores": "array shape (trace row count)",
    "PCSConfig.pm_banks": "array shape (PM bank axis)",
    "PCSConfig.policy": "composite; lowers via DrainPolicy/AllocPolicy",
    "PCSConfig.latency": "composite; lowers via the latency scalar keys",
}


def _dce_unused(closed) -> List[bool]:
    """Per-invar liveness after whole-program DCE (True = used)."""
    from jax._src.interpreters import partial_eval as pe
    jaxpr = closed.jaxpr
    _, used_inputs = pe.dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
    return list(used_inputs)


def check_traced(fn=None, args: Optional[tuple] = None,
                 names: Optional[Sequence[str]] = None,
                 anchors: Optional[Dict[str, Tuple[str, int]]] = None,
                 closed=None) -> List[Finding]:
    """Core retrace check: every named operand must survive DCE.

    Either pass a pre-traced ``closed`` jaxpr + ``names`` (the real
    engine path) or ``fn``/``args`` for the fixture corpus, where names
    default to the sorted keys of a single dict argument.
    """
    import jax

    if closed is None:
        closed = jax.make_jaxpr(fn)(*args)
        if names is None:
            flat = []
            for a in args:
                if isinstance(a, dict):
                    flat += sorted(a)
                else:
                    flat.append("arg")
            names = flat
    names = list(names)
    if len(names) != len(closed.jaxpr.invars):
        raise ValueError("operand names misaligned with jaxpr invars")
    used = _dce_unused(closed)
    findings = []
    for name, live in zip(names, used):
        if live:
            continue
        file, line = (anchors or {}).get(name, ("<traced>", 0))
        findings.append(Finding(
            file=file, line=line, rule="retrace-baked-static",
            message=(f"traced operand {name!r} is dead in the step "
                     "jaxpr: the program's results do not depend on it "
                     "(a sweepable knob was baked into a Python "
                     "constant, or its lowering is dead code)"),
            suggestion=f"consume sc[{name!r}] in the traced step, or "
                       "drop the lowering"))
    return findings


def _scalar_anchors() -> Dict[str, Tuple[str, int]]:
    """sc key -> (file, line) of its ``key=`` in scalars_from_config."""
    from repro.core.engine import state
    src, start = inspect.getsourcelines(state.scalars_from_config)
    file = rel(inspect.getsourcefile(state.scalars_from_config))
    anchors = {}
    for off, raw in enumerate(src):
        stripped = raw.strip()
        key = stripped.split("=", 1)[0].strip()
        if "=" in stripped and key.isidentifier():
            anchors.setdefault(key, (file, start + off))
    return anchors


def _field_anchor(cls, field: str) -> Tuple[str, int]:
    src, start = inspect.getsourcelines(cls)
    file = rel(inspect.getsourcefile(cls))
    line = find_line([l.rstrip("\n") for l in src],
                     rf"^\s*{field}\s*[:=]")
    return file, start + (line - 1) if line else start


def check_registered_fields(classes: Sequence[type],
                            sweepable: Optional[Dict[str, Tuple[str, ...]]]
                            = None,
                            static: Optional[Dict[str, str]] = None
                            ) -> List[Finding]:
    """Every dataclass field of ``classes`` is registered one way.

    The declaration-side half of the retrace contract, standalone so
    the fixture corpus can run it against a params-like module: a field
    missing from both registries — the classic "added a schedule knob,
    forgot to declare how it lowers" slip — fires
    ``retrace-unregistered-field``.
    """
    import dataclasses

    sweepable = SWEEPABLE_FIELDS if sweepable is None else sweepable
    static = STATIC_FIELDS if static is None else static
    findings: List[Finding] = []
    for cls in classes:
        for f in dataclasses.fields(cls):
            qual = f"{cls.__name__}.{f.name}"
            if qual in sweepable or qual in static:
                continue
            file, line = _field_anchor(cls, f.name)
            findings.append(Finding(
                file=file, line=line, rule="retrace-unregistered-field",
                message=(f"{qual} is neither registered as sweepable "
                         "(SWEEPABLE_FIELDS) nor declared static "
                         "(STATIC_FIELDS) in repro.analysis.retrace"),
                suggestion="register the field with the sc keys it "
                           "lowers to, or declare it static with a "
                           "reason"))
    return findings


def check_engine() -> List[Finding]:
    """Run the retrace pass against the real engine cell."""
    from repro.analysis._engine import scalar_keys, trace_engine
    from repro.core import params

    findings: List[Finding] = []
    anchors = _scalar_anchors()
    keys = set(scalar_keys())

    # 1. registry <-> lowering agreement
    for field, targets in SWEEPABLE_FIELDS.items():
        cls_name, fname = field.split(".")
        cls = getattr(params, cls_name)
        for key in targets:
            if key not in keys:
                file, line = _field_anchor(cls, fname)
                findings.append(Finding(
                    file=file, line=line, rule="retrace-missing-lowering",
                    message=(f"sweepable field {field} is registered to "
                             f"lower to sc[{key!r}], but "
                             "scalars_from_config emits no such key"),
                    suggestion="lower the field in scalars_from_config "
                               "or fix the registry entry"))

    # 2. every policy/config/schedule dataclass field is registered
    findings += check_registered_fields(
        [getattr(params, n)
         for n in ("PCSConfig", "DrainPolicy", "AllocPolicy", "Schedule")])

    # 3. the traced program consumes every lowered operand
    closed, names = trace_engine(return_state=False)
    findings += check_traced(closed=closed, names=names, anchors=anchors)
    return findings
