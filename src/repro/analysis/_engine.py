"""Abstract traces of the real engine cell for the jaxpr-based passes.

One small exemplar cell exercises every traced axis: PB_RF over a
2-leaf fan-out fabric (deep-hop rows live for the spine, per-leaf PBC
column live, finite backpressure watermark), 2 tenants with quotas +
weighted victim, a tenant-scoped drain policy with a latency target, a
finite crash point, durability tracking and macro-stepping.  Tracing it with
``jax.make_jaxpr`` is seconds (no XLA compile), so the passes run at
test speed.

The trace arrays are tiny but cover every op kind — the handler
dispatch is a ``lax.switch`` over all six handlers, so every handler
body (and therefore every ``sc`` consumer) is traced regardless of
which ops the exemplar trace actually issues.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import numpy as np


def _example_inputs():
    from repro.core.engine.state import scalars_from_config
    from repro.core.params import (AllocPolicy, DrainPolicy, FabricTopology,
                                   Op, PBPolicy, PCSConfig, Schedule,
                                   Scheme, MACRO_KMAX)
    from repro.core.traces import plan_runs

    # the 2-leaf fabric (finite backpressure watermark) keeps the fabric
    # operands (n_leaves/leaf_of_t/leaf_base/bp_high) live under DCE and
    # derives the same (8, 4) hop capacities as the old explicit chain;
    # every schedulable knob is a 2-EPOCH Schedule (one shared boundary)
    # so DCE proves the epoch_bounds vector and the stacked per-epoch
    # rows feed the results, not just epoch 0's slice
    bound = 2.5e4
    cfg = PCSConfig(
        scheme=Scheme.PB_RF, n_cores=4,
        n_tenants=2, crash_at_ns=5.0e4,
        fabric=FabricTopology(n_leaves=2, leaf_pbe=(4, 4), spine_pbe=4,
                              placement=Schedule((bound,),
                                                 ((0, 1), (1, 0))),
                              bp_high=3.0),
        policy=PBPolicy(
            drain=DrainPolicy(
                per_tenant=True,
                threshold=Schedule((bound,), (0.75, 0.5)),
                preset=0.25,
                latency_target_ns=Schedule((bound,), (450.0, 300.0))),
            alloc=AllocPolicy(victim="weighted",
                              tenant_quota=Schedule((bound,),
                                                    ((4, 4), (3, 5))))))
    sc = scalars_from_config(cfg, n_tenants_max=2, n_deep_max=1,
                             n_leaves_max=2, n_epochs_max=2)

    C, L = 4, 16 + MACRO_KMAX
    kinds = [Op.PERSIST, Op.PM_READ, Op.DRAM_READ, Op.DRAM_WRITE,
             Op.COMPUTE, Op.PERSIST, Op.PM_READ, Op.BARRIER]
    ops = np.zeros((C, L), np.int32)
    addrs = np.zeros((C, L), np.int32)
    gaps = np.zeros((C, L), np.float32)
    for c in range(C):
        for i in range(16):
            ops[c, i] = int(kinds[i % len(kinds)])
            addrs[c, i] = (c * 16 + i) % 8
            gaps[c, i] = 10.0
    lengths = np.full((C,), 16, np.int32)
    mlen = plan_runs(ops, addrs, gaps, MACRO_KMAX)
    statics = dict(max_pbe=8, n_steps=32, pm_banks=2, n_track=4,
                   n_tenants_max=2, n_deep_max=1, n_leaves_max=2,
                   macro=True)
    # device arrays, as simulate_grid stages them: numpy closures would
    # reject tracer indices during abstract tracing
    import jax.numpy as jnp
    buffers = tuple(jnp.asarray(b) for b in (ops, addrs, gaps, lengths,
                                             mlen))
    return buffers, statics, sc


@functools.lru_cache(maxsize=2)
def trace_engine(return_state: bool = False):
    """``(closed_jaxpr, operand_names)`` of one exemplar engine cell.

    ``operand_names`` aligns positionally with ``jaxpr.invars``:
    ``"scheme"`` followed by the sorted ``sc`` keys (dict pytrees
    flatten in sorted-key order).  Cached per flag — the retrace pass
    wants the results-only program (dead telemetry prunes back to its
    inputs), the dtype pass wants the final carry too.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.engine.step import scan_cell

    (ops, addrs, gaps, lengths, mlen), statics, sc = _example_inputs()

    def cell(scheme, sc):
        return scan_cell(ops, addrs, gaps, lengths, scheme, sc,
                         mlen=mlen, return_state=return_state, **statics)

    with enable_x64():
        sc_j = {k: jnp.asarray(v, jnp.float64) for k, v in sc.items()}
        closed = jax.make_jaxpr(cell)(jnp.asarray(2, jnp.int32), sc_j)
    names = ["scheme"] + sorted(sc_j)
    if len(names) != len(closed.jaxpr.invars):
        raise RuntimeError(
            f"operand-name alignment broke: {len(names)} names vs "
            f"{len(closed.jaxpr.invars)} invars")
    return closed, names


def scalar_keys() -> List[str]:
    """Every key ``scalars_from_config`` lowers (the sweepable surface)."""
    _, _, sc = _example_inputs()
    return sorted(sc)


def final_state_shapes() -> Dict[str, Tuple[str, Tuple[int, ...]]]:
    """column -> (dtype, shape) of the scan carry AFTER a full cell run
    (``jax.eval_shape``: abstract, no compile).  Catches a handler that
    silently widens a packed column just as well as an init-time
    regression — the carry must round-trip every step."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.engine.step import scan_cell

    (ops, addrs, gaps, lengths, mlen), statics, sc = _example_inputs()

    def final_state(scheme, sc):
        out = scan_cell(ops, addrs, gaps, lengths, scheme, sc,
                        mlen=mlen, return_state=True, **statics)
        return out[-1]

    with enable_x64():
        sc_j = {k: jnp.asarray(v, jnp.float64) for k, v in sc.items()}
        st = jax.eval_shape(final_state, jnp.asarray(2, jnp.int32), sc_j)
    return {k: (str(v.dtype), tuple(v.shape))
            for k, v in st._asdict().items()}
