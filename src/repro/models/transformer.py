"""Composable transformer stack covering all 10 assigned architectures.

A model is a ``ModelConfig`` whose ``block_pattern`` is a short repeating
tuple of layer specs; parameters of each block position are *stacked*
across repetitions and the stack is executed with ``jax.lax.scan``.  This
keeps the lowered HLO size O(block) instead of O(n_layers) — essential
for compiling 95-layer models on a 512-device mesh in reasonable time.

Layer spec kinds:
    "attn"  — global self-attention (GQA + RoPE)
    "swa"   — sliding-window self-attention (gemma local, mixtral SWA)
    "ssm"   — Mamba2 SSD mixer
Each spec also carries ``moe`` (expert FFN instead of dense) — dense FFN
is skipped entirely when ``d_ff == 0`` (pure mamba2).

Supported topologies:
    * decoder-only LM (most archs)
    * prefix-LM with stub patch embeddings (paligemma)
    * encoder-decoder with stub frame embeddings + cross-attention
      (seamless-m4t)

Serving: the same block code runs prefill (S = prompt, writes the KV /
SSM caches) and decode (S = 1 against the caches).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.attention import KVCache, attention, init_attention, init_kv_cache
from repro.models.ssm import SSMCache, init_ssm_cache


class LayerSpec(NamedTuple):
    kind: str          # "attn" | "swa" | "ssm"
    moe: bool = False


# ---------------------------------------------------------------------
# Activation sharding: GSPMD's propagation through a scanned while-body
# can default to replicated (observed: full-batch f32 attention scores).
# The launcher installs a PartitionSpec for the batch axes here and the
# stack re-constrains the residual stream every block iteration.
# ---------------------------------------------------------------------
_ACT_SPEC: list = [None]


class activation_sharding:
    """Context manager: constrain (B, S, d) activations to this spec."""

    def __init__(self, spec):
        self.spec = spec

    def __enter__(self):
        _ACT_SPEC.append(self.spec)

    def __exit__(self, *exc):
        _ACT_SPEC.pop()


def _constrain(h):
    spec = _ACT_SPEC[-1]
    if spec is None:
        return h
    return jax.lax.with_sharding_constraint(h, spec)


# GShard-style MoE routing groups (see models/moe.py): the launcher sets
# this to the data-parallel shard count so dispatch stays shard-local.
_MOE_GROUPS: list = [1]


class moe_groups:
    def __init__(self, n: int):
        self.n = max(int(n), 1)

    def __enter__(self):
        _MOE_GROUPS.append(self.n)

    def __exit__(self, *exc):
        _MOE_GROUPS.pop()


# Cost-probe mode: execute the layer stack as a Python loop instead of
# lax.scan.  XLA's cost_analysis counts a while-loop body once regardless
# of trip count; the dry-run compiles shallow UNROLLED variants and
# extrapolates linearly to the real depth (see launch/dryrun.py).
_UNROLL: list = [False]


class unrolled_stack:
    def __enter__(self):
        _UNROLL.append(True)

    def __exit__(self, *exc):
        _UNROLL.pop()


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn"),)
    head_dim: Optional[int] = None
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # for "swa" layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qk_norm: bool = False
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    n_enc_layers: int = 0                 # > 0 => encoder-decoder
    frontend: Optional[str] = None        # None | "audio" | "vision"
    frontend_seq: int = 0                 # stub prefix length (vision)
    remat: bool = True
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, (
            self.name, self.n_layers, len(self.block_pattern))

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def reps(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attn_free(self) -> bool:
        return all(s.kind == "ssm" for s in self.block_pattern)

    @property
    def full_attention_only(self) -> bool:
        """True when every token-mixing layer is global attention."""
        return all(s.kind == "attn" for s in self.block_pattern)

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline accounting)."""
        leaves = jax.tree.leaves(
            jax.eval_shape(lambda: init_params(self, jax.random.key(0))))
        return sum(math.prod(x.shape) for x in leaves)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        total = self.param_count()
        if self.n_experts == 0:
            return total
        n_moe = sum(s.moe for s in self.block_pattern) * self.reps
        expert = 3 * self.d_model * self.d_ff
        inactive = n_moe * (self.n_experts - self.top_k) * expert
        return total - inactive


# =========================================================================
# init
# =========================================================================

def _init_layer(key, spec: LayerSpec, cfg: ModelConfig, cross: bool) -> dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model)}
    if spec.kind in ("attn", "swa"):
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd,
                                   qk_norm=cfg.qk_norm, dtype=cfg.dtype)
    else:
        p["ssm"] = S.init_ssd(ks[0], cfg.d_model, d_state=cfg.ssm_state,
                              expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                              dtype=cfg.dtype)
    if cross:
        p["ln_cross"] = L.init_rmsnorm(cfg.d_model)
        p["cross"] = init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, dtype=cfg.dtype)
    if cfg.d_ff > 0:
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        if spec.moe:
            p["moe"] = M.init_moe(ks[2], cfg.d_model, cfg.d_ff,
                                  cfg.n_experts, dtype=cfg.dtype)
        else:
            p["ffn"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def _init_stack(key, cfg: ModelConfig, n_layers: int, cross: bool,
                pattern: Tuple[LayerSpec, ...]) -> List[dict]:
    """One stacked pytree per block position (leading dim = reps)."""
    reps = n_layers // len(pattern)
    out = []
    for j, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), reps)
        out.append(jax.vmap(
            lambda k: _init_layer(k, spec, cfg, cross))(keys))
    return out


def init_params(cfg: ModelConfig, key) -> dict:
    k_emb, k_dec, k_enc = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "blocks": _init_stack(k_dec, cfg, cfg.n_layers,
                              cross=cfg.is_enc_dec, pattern=cfg.block_pattern),
    }
    if cfg.is_enc_dec:
        params["enc_blocks"] = _init_stack(
            k_enc, cfg, cfg.n_enc_layers, cross=False,
            pattern=(LayerSpec("attn"),))
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    return params


# =========================================================================
# forward
# =========================================================================

def _apply_layer(spec: LayerSpec, p: dict, cfg: ModelConfig, h, positions, *,
                 causal, prefix_len, cache, enc_out, enc_pos):
    new_cache = None
    hin = L.rmsnorm(p["ln1"], h)
    if spec.kind in ("attn", "swa"):
        window = cfg.window if spec.kind == "swa" else None
        theta = cfg.rope_theta if spec.kind == "attn" else 10_000.0
        y, new_cache = attention(
            p["attn"], hin, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            causal=causal, window=window, attn_softcap=cfg.attn_softcap,
            rope_theta=theta, cache=cache, prefix_len=prefix_len)
    else:
        y, new_cache = S.ssd_block(
            p["ssm"], hin, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            cache=cache)
    h = h + y

    if "cross" in p:
        hin = L.rmsnorm(p["ln_cross"], h)
        y, _ = attention(
            p["cross"], hin, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            causal=False, kv_x=enc_out, kv_positions=enc_pos, use_rope=False)
        h = h + y

    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        hin = L.rmsnorm(p["ln2"], h)
        if spec.moe:
            y, aux = M.moe_ffn(p["moe"], hin, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               drop=cache is None,
                               groups=_MOE_GROUPS[-1])
        else:
            y = L.mlp(p["ffn"], hin)
        h = h + y
    return h, new_cache, aux


def _run_stack(cfg: ModelConfig, stacked: List[dict],
               pattern: Tuple[LayerSpec, ...], h, positions, *,
               causal=True, prefix_len=None, caches=None,
               enc_out=None, enc_pos=None, remat=False):
    """Scan the repeating block over its stacked parameters."""
    decode = caches is not None

    def body(carry, xs):
        h, aux = carry
        ps = xs[0]
        cs = xs[1] if decode else [None] * len(pattern)
        new_cs = []
        h = _constrain(h)
        for j, spec in enumerate(pattern):
            h, nc, a = _apply_layer(
                spec, ps[j], cfg, h, positions, causal=causal,
                prefix_len=prefix_len, cache=cs[j], enc_out=enc_out,
                enc_pos=enc_pos)
            h = _constrain(h)
            new_cs.append(nc if decode else None)
            aux = aux + a
        return (h, aux), (tuple(new_cs) if decode else None)

    if remat and not decode:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stacked, caches) if decode else (stacked,)
    if _UNROLL[-1]:
        reps = jax.tree.leaves(stacked)[0].shape[0]
        carry = (h, jnp.zeros((), jnp.float32))
        ys = []
        for i in range(reps):
            xi = jax.tree.map(lambda x: x[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        h, aux = carry
        new_caches = (jax.tree.map(lambda *t: jnp.stack(t), *ys)
                      if decode else None)
        return h, new_caches, aux
    (h, aux), new_caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs)
    return h, new_caches, aux


def _embed_in(cfg: ModelConfig, params, tokens):
    h = L.embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    return h.astype(cfg.dtype)


def _logits_out(cfg: ModelConfig, params, h):
    h = L.rmsnorm(params["final_norm"], h)
    logits = L.unembed(params["embed"], h).astype(jnp.float32)
    return L.softcap(logits, cfg.final_softcap)


def _encode(cfg: ModelConfig, params, enc_embeds):
    """Run the (stub-fronted) encoder over precomputed frame embeddings."""
    s_enc = enc_embeds.shape[1]
    pos = jnp.arange(s_enc)
    h = enc_embeds.astype(cfg.dtype)
    h, _, _ = _run_stack(cfg, params["enc_blocks"], (LayerSpec("attn"),),
                         h, pos, causal=False, remat=cfg.remat)
    return L.rmsnorm(params["enc_norm"], h), pos


def forward(cfg: ModelConfig, params: dict, batch: Dict[str, jnp.ndarray]):
    """Training-mode forward.  Returns (logits, aux_loss).

    batch keys:
        tokens       (B, S) int32            — decoder input ids
        enc_embeds   (B, S_enc, d) optional  — audio-frontend stub output
        prefix_embeds(B, P, d)    optional   — vision-frontend stub output
    """
    tokens = batch["tokens"]
    h = _embed_in(cfg, params, tokens)
    prefix_len = None
    if cfg.frontend == "vision" and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cfg.dtype)
        h = jnp.concatenate([pre, h], axis=1)
        prefix_len = pre.shape[1]
    positions = jnp.arange(h.shape[1])

    enc_out = enc_pos = None
    if cfg.is_enc_dec:
        enc_out, enc_pos = _encode(cfg, params, batch["enc_embeds"])

    h, _, aux = _run_stack(cfg, params["blocks"], cfg.block_pattern, h,
                           positions, causal=True, prefix_len=prefix_len,
                           enc_out=enc_out, enc_pos=enc_pos, remat=cfg.remat)
    if prefix_len is not None:
        h = h[:, prefix_len:]
    return _logits_out(cfg, params, h), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: Dict[str, jnp.ndarray]):
    """Next-token cross-entropy (labels = batch['labels'], -1 = ignore).

    Written in logsumexp/one-hot form (no gather over the vocab axis) so
    the vocab-sharded logits never need to be replicated: both reductions
    are plain sums over the sharded axis, which GSPMD partial-reduces.
    """
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    log_z = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(lab, cfg.vocab, dtype=logits.dtype)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    nll = log_z - true_logit
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss + 0.01 * aux


# =========================================================================
# serving (prefill + decode)
# =========================================================================

def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (reps-leading) caches, one entry per block position."""
    caches = []
    for spec in cfg.block_pattern:
        if spec.kind == "ssm":
            c = init_ssm_cache(batch, cfg.d_model, d_state=cfg.ssm_state,
                               expand=cfg.ssm_expand,
                               head_dim=cfg.ssm_head_dim, dtype=cfg.dtype)
        else:
            win = cfg.window if spec.kind == "swa" else None
            alloc = min(max_len, win) if win else max_len
            c = init_kv_cache(batch, alloc, cfg.n_kv_heads, cfg.hd, cfg.dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.reps,) + x.shape), c))
    return caches


def _serve(cfg: ModelConfig, params, h, positions, caches, *,
           prefix_len=None, enc_out=None, enc_pos=None):
    h, new_caches, _ = _run_stack(
        cfg, params["blocks"], cfg.block_pattern, h, positions,
        causal=True, prefix_len=prefix_len, caches=caches,
        enc_out=enc_out, enc_pos=enc_pos)
    return _logits_out(cfg, params, h), new_caches


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Run the prompt through the model, seeding the caches."""
    enc_out = enc_pos = None
    if cfg.is_enc_dec:
        enc_out, enc_pos = _encode(cfg, params, batch["enc_embeds"])
    tokens = batch["tokens"]
    h = _embed_in(cfg, params, tokens)
    prefix_len = None
    if cfg.frontend == "vision" and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cfg.dtype)
        h = jnp.concatenate([pre, h], axis=1)
        prefix_len = pre.shape[1]
    caches = init_caches(cfg, tokens.shape[0], max_len)
    positions = jnp.arange(h.shape[1])
    logits, caches = _serve(cfg, params, h, positions, caches,
                            prefix_len=prefix_len,
                            enc_out=enc_out, enc_pos=enc_pos)
    return logits[:, -1], caches


def decode_step(cfg: ModelConfig, params, tokens_last, caches, *,
                pos0=None, enc_out=None, enc_pos=None):
    """One decode step.  tokens_last: (B, 1).  Returns (logits, caches).

    ``pos0`` overrides the query position (required for attention-free
    models, whose caches carry no position counter).
    """
    if pos0 is None:
        pos0 = _cache_len(cfg, caches)
    h = _embed_in(cfg, params, tokens_last)
    positions = pos0 + jnp.arange(tokens_last.shape[1])
    logits, caches = _serve(cfg, params, h, positions, caches,
                            enc_out=enc_out, enc_pos=enc_pos)
    return logits[:, -1], caches


def _cache_len(cfg: ModelConfig, caches):
    for spec, c in zip(cfg.block_pattern, caches):
        if spec.kind != "ssm":
            return c.length[0]
    # attention-free model: SSM state has no position; use a counter the
    # caller threads (decode positions only matter for RoPE in attention)
    return jnp.zeros((), jnp.int32)
