"""Grouped-query attention with RoPE, sliding windows, softcap, KV cache.

One attention implementation serves every assigned architecture:

    * GQA (n_kv_heads <= n_heads), MQA when n_kv_heads == 1 (paligemma)
    * causal, non-causal (encoder), prefix-LM, and cross-attention
    * sliding-window masks (gemma2/3 local layers, mixtral SWA)
    * gemma2-style attention-logit softcapping, gemma3-style qk-norm
    * decode against a preallocated KV cache; sliding-window layers use a
      RING cache of `window` slots (each slot stores its absolute
      position), which is what makes mixtral/gemma long-context decode
      sub-quadratic in memory.

The pure-jnp path below is the reference; ``repro.kernels.flash_attention``
is the Pallas TPU kernel for the same contraction (used on real hardware;
the dry-run lowers this jnp path, which XLA fuses on TPU).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, init_linear, init_rmsnorm,
                                 linear, rmsnorm, softcap)


class KVCache(NamedTuple):
    """Preallocated decode cache for one attention layer (ring buffer)."""

    k: jnp.ndarray       # (B, S_alloc, Hkv, Dh)
    v: jnp.ndarray       # (B, S_alloc, Hkv, Dh)
    pos: jnp.ndarray     # (S_alloc,) int32 — absolute position per slot, -1 empty
    length: jnp.ndarray  # () int32 — total tokens seen so far


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qk_norm: bool = False, dtype=None) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_linear(kq, d_model, n_heads * head_dim, dtype),
        "wk": init_linear(kk, d_model, n_kv_heads * head_dim, dtype),
        "wv": init_linear(kv, d_model, n_kv_heads * head_dim, dtype),
        "wo": init_linear(ko, n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(head_dim)
        p["k_norm"] = init_rmsnorm(head_dim)
    return p


def init_kv_cache(batch: int, alloc: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, alloc, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, alloc, n_kv_heads, head_dim), dtype),
        pos=jnp.full((alloc,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def make_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, *, causal: bool,
              window: Optional[int], prefix_len=None) -> jnp.ndarray:
    """(S, T) boolean attend-mask from absolute positions (-1 k = empty)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    if prefix_len is not None:
        m |= (k_pos[None, :] < prefix_len) & jnp.ones_like(m)
    m &= (k_pos >= 0)[None, :]
    return m


def _sdpa(q, k, v, *, mask, cap: Optional[float]) -> jnp.ndarray:
    """q: (B,S,Hkv,G,D)  k/v: (B,T,Hkv,D)  mask: (S,T) or None."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bshgd,bthd->bhgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = softcap(logits, cap)
    if mask is not None:
        neg = jnp.finfo(jnp.float32).min
        logits = jnp.where(mask[None, None, None], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attention(p: dict, x: jnp.ndarray, positions: jnp.ndarray, *,
              n_heads: int, n_kv_heads: int, head_dim: int,
              causal: bool = True, window: Optional[int] = None,
              attn_softcap: Optional[float] = None,
              rope_theta: float = 10_000.0,
              prefix_len=None,
              cache: Optional[KVCache] = None,
              kv_x: Optional[jnp.ndarray] = None,
              kv_positions: Optional[jnp.ndarray] = None,
              use_rope: bool = True):
    """Self- or cross-attention.

    * training: ``cache=None`` -> (y, None)
    * prefill/decode: ``cache`` given (ring buffer) -> (y, new_cache)
    * cross-attention: ``kv_x`` = encoder output, no cache, no RoPE.

    ``positions``: (S,) absolute positions of the query tokens.
    """
    g = n_heads // n_kv_heads
    b, s = x.shape[0], x.shape[1]
    q = _split_heads(linear(p["wq"], x), n_heads)
    src = x if kv_x is None else kv_x
    k = _split_heads(linear(p["wk"], src), n_kv_heads)
    v = _split_heads(linear(p["wv"], src), n_kv_heads)

    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)

    k_pos_new = positions if kv_x is None else kv_positions
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, k_pos_new, rope_theta)

    if cache is not None:
        alloc = cache.k.shape[1]
        # ring write; when the (static) update is longer than the ring,
        # only the last `alloc` tokens survive — drop the rest up front so
        # the scatter indices stay unique.
        kw, vw, posw, start, n_w = k, v, k_pos_new, cache.length, s
        if s > alloc:
            kw, vw, posw = k[:, -alloc:], v[:, -alloc:], k_pos_new[-alloc:]
            start, n_w = cache.length + (s - alloc), alloc
        slots = (start + jnp.arange(n_w)) % alloc
        kc = cache.k.at[:, slots].set(kw.astype(cache.k.dtype))
        vc = cache.v.at[:, slots].set(vw.astype(cache.v.dtype))
        posc = cache.pos.at[slots].set(posw.astype(jnp.int32))
        new_cache = KVCache(kc, vc, posc, cache.length + s)
        if s > 1:
            # prefill: attend over the full fresh K/V (early queries need
            # keys that the ring has already evicted); the ring only keeps
            # the tail for subsequent decode steps.
            mask = make_mask(positions, k_pos_new, causal=causal,
                             window=window, prefix_len=prefix_len)
            k_use, v_use = k, v
        else:
            mask = make_mask(positions, posc, causal=causal, window=window,
                             prefix_len=prefix_len)
            k_use, v_use = kc, vc
    else:
        new_cache = None
        mask = None
        if causal or window is not None or kv_x is None:
            mask = make_mask(positions, k_pos_new, causal=causal,
                             window=window, prefix_len=prefix_len)
        k_use, v_use = k, v

    qg = q.reshape(b, s, n_kv_heads, g, head_dim)
    out = _sdpa(qg, k_use, v_use, mask=mask, cap=attn_softcap)
    out = out.reshape(b, s, n_heads * head_dim)
    return linear(p["wo"], out), new_cache
