"""Mamba2 SSD (state-space duality) mixer — pure-JAX reference.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060):
within a chunk the recurrence is materialized as a masked attention-like
contraction (the "dual" form, MXU-friendly); chunk boundary states are
propagated with a ``lax.scan``.  A Pallas TPU kernel of the inner chunk
computation lives in ``repro.kernels.ssd_scan`` and is validated against
this module.

Used by ``mamba2-1.3b`` (pure SSM) and ``jamba-1.5-large`` (1:7
attn:mamba hybrid).  Decode keeps O(1) per-token state:
``state: (B, H, P, N)`` plus a depthwise-conv ring buffer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, K-1, conv_dim) ring of last K-1 inputs
    state: jnp.ndarray  # (B, H, P, N) SSD recurrent state (fp32)


def init_ssd(key, d_model: int, *, d_state: int = 128, expand: int = 2,
             head_dim: int = 64, conv_kernel: int = 4, dtype=None) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.log(jnp.exp(jnp.linspace(1e-3, 0.1, n_heads)) - 1.0)  # softplus^-1
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "in_proj": init_linear(k1, d_model, 2 * d_inner + 2 * d_state + n_heads, dtype),
        "conv_w": (jax.random.normal(k2, (conv_kernel, conv_dim)) * 0.1).astype(dtype or jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt.astype(jnp.float32),
        "out_proj": init_linear(k3, d_inner, d_model, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 carry: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B,S,C), w: (K,C).  Returns (y, new_carry)."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    y = y + b.astype(y.dtype)
    return y, xp[:, -(k - 1):]


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} log_a[..., k]."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x:  (B, S, H, P)   input heads
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates
    B:  (B, S, N)      input->state projection (shared across heads, g=1)
    C:  (B, S, N)      state->output projection
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad to a chunk multiple with dt=0 steps (exact identity: decay
        # exp(0)=1 and zero input contribution), then slice the result.
        pad = chunk - s % chunk
        y, final = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(B, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(C, ((0, 0), (0, pad), (0, 0))),
            chunk=chunk, init_state=init_state)
        return y[:, :s], final
    nc = s // chunk
    f32 = jnp.float32

    xc = x.astype(f32).reshape(b, nc, chunk, h, p)
    dtc = dt.astype(f32).reshape(b, nc, chunk, h)
    Bc = B.astype(f32).reshape(b, nc, chunk, n)
    Cc = C.astype(f32).reshape(b, nc, chunk, n)
    dA = dtc * A[None, None, None, :]            # (b,nc,q,h) log-decay per step

    # intra-chunk (dual/attention form)
    seg = _segsum(jnp.moveaxis(dA, -1, -2))       # (b,nc,h,q,q)
    L = jnp.exp(seg)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)          # (b,nc,q,q)
    CB = scores[:, :, None, :, :] * L                       # (b,nc,h,q,k)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", CB, dtc, xc)

    # chunk summaries: decayed input->state
    dA_cum = jnp.cumsum(dA, axis=2)               # (b,nc,q,h)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        Bc, dtc * decay_to_end, xc)         # (b,nc,h,p,n)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])    # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_in, dec = inp                           # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st_in
        return new, carry                          # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n)

    # contribution of the carried-in state to each position
    state_decay = jnp.exp(dA_cum)                  # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, A, B, C, state):
    """One-token recurrence.  x:(B,H,P) dt:(B,H) B/C:(B,N) state:(B,H,P,N)."""
    f32 = jnp.float32
    x, dt, B, C = (t.astype(f32) for t in (x, dt, B, C))
    decay = jnp.exp(dt * A[None, :])                       # (B,H)
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bh,bhp,bn->bhpn", dt, x, B))
    y = jnp.einsum("bn,bhpn->bhp", C, new_state)
    return y, new_state


def ssd_block(p: dict, x: jnp.ndarray, *, d_state: int, head_dim: int,
              chunk: int = 128, cache: Optional[SSMCache] = None):
    """Full Mamba2 block: in_proj -> conv -> SSD -> gate -> out_proj.

    Training/prefill: cache=None, x (B,S,d).  Decode: x (B,1,d) + cache.
    """
    b, s, d = x.shape
    d_inner = p["out_proj"]["w"].shape[0]
    h = d_inner // head_dim
    zxbcdt = linear(p["in_proj"], x)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * d_state], axis=-1)

    conv_carry = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_carry)
    xbc = jax.nn.silu(xbc)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(b, s, h, head_dim)

    if cache is not None and s == 1:
        y1, new_state = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], cache.state)
        y = y1[:, None].astype(x.dtype)
        new_cache = SSMCache(conv=new_conv, state=new_state)
    else:
        # training (no cache) or multi-token prefill: chunked scan,
        # seeded from the cached state when one is threaded through
        y, final = ssd_chunked(
            xh, dt, A, B, C, chunk=chunk,
            init_state=cache.state if cache is not None else None)
        new_cache = SSMCache(conv=new_conv, state=final)

    y = y + p["D"][None, None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y), new_cache


def init_ssm_cache(batch: int, d_model: int, *, d_state: int = 128,
                   expand: int = 2, head_dim: int = 64, conv_kernel: int = 4,
                   dtype=jnp.bfloat16) -> SSMCache:
    d_inner = expand * d_model
    h = d_inner // head_dim
    return SSMCache(
        conv=jnp.zeros((batch, conv_kernel - 1, d_inner + 2 * d_state), dtype),
        state=jnp.zeros((batch, h, head_dim, d_state), jnp.float32),
    )
