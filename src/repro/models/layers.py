"""Shared neural-net building blocks (pure functional JAX, no flax).

Every module is a pair of functions:
    init_<name>(key, ...) -> params (a pytree of jnp arrays)
    <name>(params, x, ...) -> y

Parameter trees are plain nested dicts so they stay trivially
pjit/shard_map-shardable and checkpointable through repro.persistence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def default_dtype() -> jnp.dtype:
    return jnp.bfloat16


# --------------------------------------------------------------------- norm
def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) so zero-init is identity
    return (x * (1.0 + p["scale"])).astype(dt)


# ---------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int, dtype=None) -> dict:
    dtype = dtype or default_dtype()
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied readout: (..., d) @ (vocab, d)^T -> (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, p["table"])


# ------------------------------------------------------------------- linear
def init_linear(key, d_in: int, d_out: int, dtype=None) -> dict:
    dtype = dtype or default_dtype()
    scale = 1.0 / jnp.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)
    return {"w": w}


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...i,io->...o", x, p["w"])


# ------------------------------------------------------------ gated MLP
def init_mlp(key, d: int, d_ff: int, dtype=None) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, d_ff, dtype),
        "up": init_linear(k2, d, d_ff, dtype),
        "down": init_linear(k3, d_ff, d, dtype),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU feed-forward (llama/gemma/mixtral family)."""
    h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    return linear(p["down"], h)


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, head_dim, 2, jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, Dh), positions: (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)  # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ softcap
def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
