"""Mixture-of-Experts feed-forward with capacity-based top-k dispatch.

The dispatch/combine formulation (one-hot einsums with a per-expert
capacity) is the TPU-native pattern: expert compute is a single batched
einsum over the expert dimension, which shards cleanly as expert
parallelism (experts on the 'model' mesh axis) or as FSDP+TP.  Active
FLOPs are ``top_k * capacity_factor`` times one dense expert — matching
how mixtral/phi-3.5/jamba actually run.

Tokens overflowing an expert's capacity are dropped (standard practice;
the residual stream carries them unchanged).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import default_dtype, init_linear


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *, dtype=None) -> dict:
    dtype = dtype or default_dtype()
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(d_ff)

    def ew(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    return {
        "router": init_linear(kr, d_model, n_experts, jnp.float32),
        "gate": ew(kg, (n_experts, d_model, d_ff), scale_in),
        "up": ew(ku, (n_experts, d_model, d_ff), scale_in),
        "down": ew(kd, (n_experts, d_ff, d_model), scale_out),
    }


def moe_ffn(p: dict, x: jnp.ndarray, *, top_k: int = 2,
            capacity_factor: float = 1.25,
            drop: bool = True, groups: int = 1
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss).  x: (B, S, d).

    ``drop=False`` (serving): capacity covers every token, so routing is
    batch-composition independent — decode must match teacher forcing.

    ``groups`` > 1 (GShard-style local groups): tokens are split into
    ``groups`` independent routing groups with per-group capacity.  When
    ``groups`` equals the data-parallel shard count, every cumsum /
    scatter / gather in the dispatch stays shard-local, so the only MoE
    communication left is the dense TP partial-sum — without this, GSPMD
    replicates the global dispatch buffer on every device (measured: the
    dominant collective for mixtral/phi/jamba, EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    e = p["router"]["w"].shape[1]
    n_total = b * s
    if groups > 1 and n_total % groups == 0:
        xg = x.reshape(groups, n_total // groups, d)
        yg, aux = jax.vmap(
            lambda xi: _moe_group(p, xi, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  drop=drop))(xg)
        return yg.reshape(b, s, d), jnp.mean(aux)
    y, aux = _moe_group(p, x.reshape(n_total, d), top_k=top_k,
                        capacity_factor=capacity_factor, drop=drop)
    return y.reshape(b, s, d), aux


def _moe_group(p: dict, xt: jnp.ndarray, *, top_k: int,
               capacity_factor: float, drop: bool):
    """Route one token group.  xt: (n, d)."""
    n, d = xt.shape
    e = p["router"]["w"].shape[1]
    cap = n if not drop else max(int(capacity_factor * top_k * n / e), 1)
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k assignment (expert ids + gate weights per round)
    idxs, gvals = [], []
    remaining = probs
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                 # (n,)
        idxs.append(idx)
        gvals.append(jnp.take_along_axis(probs, idx[:, None], 1)[:, 0])
        remaining = remaining * (1.0 - jax.nn.one_hot(idx, e, dtype=remaining.dtype))

    # INDEX-BASED dispatch (no one-hot matmuls: routing is gather/scatter
    # and contributes zero FLOPs, like a real ragged MoE kernel).
    expert_flat = jnp.concatenate(idxs)                      # (n*k,)
    gate_flat = jnp.concatenate(gvals)                       # (n*k,)
    token_flat = jnp.tile(jnp.arange(n), top_k)
    # position of each assignment within its expert's buffer
    onehot_pos = (expert_flat[:, None] ==
                  jnp.arange(e)[None, :]).astype(jnp.int32)  # (n*k, e)
    pos = (jnp.cumsum(onehot_pos, axis=0) - onehot_pos)[
        jnp.arange(n * top_k), expert_flat]                  # (n*k,)
    keep = pos < cap
    buf = jnp.where(keep, expert_flat * cap + pos, e * cap)  # drop slot -> pad

    # scatter tokens into the (e*cap [+1 pad], d) buffer
    xe = jnp.zeros((e * cap + 1, d), xt.dtype).at[buf].set(xt[token_flat])
    xe = xe[:-1].reshape(e, cap, d)

    # expert FFN in model dtype with fp32 accumulation (MXU-native)
    f32 = jnp.float32
    hg = jnp.einsum("ecd,edf->ecf", xe, p["gate"],
                    preferred_element_type=f32)
    hu = jnp.einsum("ecd,edf->ecf", xe, p["up"],
                    preferred_element_type=f32)
    h = (jax.nn.silu(hg) * hu).astype(xt.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"],
                    preferred_element_type=f32)              # (e, cap, d)

    # combine: gather each assignment's output and weight by its gate
    ye_pad = jnp.concatenate(
        [ye.reshape(e * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye_pad[buf] * (gate_flat * keep)[:, None]      # (n*k, d)
    y = jnp.zeros((n, d), f32).at[token_flat].add(contrib)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.zeros((e,), f32).at[expert_flat].add(1.0) / (n * top_k)
    pe = jnp.mean(probs, axis=0)                             # router mass
    aux = e * jnp.sum(me * pe)
    return y.astype(xt.dtype), aux
