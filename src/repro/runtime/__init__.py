from repro.runtime.failures import FailureDetector, NodeStatus
from repro.runtime.elastic import plan_mesh
from repro.runtime.straggler import StragglerMitigator

__all__ = ["FailureDetector", "NodeStatus", "plan_mesh",
           "StragglerMitigator"]
