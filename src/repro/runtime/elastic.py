"""Elastic remesh planning: re-solve (pod, data, model) for survivors.

When nodes die, training restarts from the newest acked checkpoint on a
smaller mesh.  The planner keeps the model axis (set by memory, must
divide the weights) and shrinks the data axis, preserving global batch
via gradient accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    grad_accum: int          # microbatches to keep the global batch
    dropped_chips: int

    @property
    def n_chips(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


def plan_mesh(available_chips: int, *, model_parallel: int = 16,
              target_data_parallel: int = 16,
              pods: int = 1) -> Optional[MeshPlan]:
    """Largest (pod, data, model) mesh that fits the surviving chips.

    The model axis is fixed (weight shards must stay complete); data
    parallel shrinks to the largest feasible power-of-two slice, and the
    lost throughput is made up with gradient accumulation.
    """
    per_pod = available_chips // pods
    dp = per_pod // model_parallel
    if dp < 1:
        return None
    used = pods * dp * model_parallel
    accum = max(1, -(-target_data_parallel // dp))  # ceil
    if pods > 1:
        return MeshPlan((pods, dp, model_parallel),
                        ("pod", "data", "model"), accum,
                        available_chips - used)
    return MeshPlan((dp, model_parallel), ("data", "model"), accum,
                    available_chips - used)
