"""Heartbeat-based failure detection (in-process simulation harness).

At 1000+ node scale, node failure is routine; the trainer composes this
detector with the PCS checkpoint tier: on failure it restores the newest
acked version — from the host-buffer tier when Read Forwarding still
holds it (fast path), else from the durable store.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Dict, List, Optional


class NodeStatus(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class _Node:
    last_beat: float
    status: NodeStatus = NodeStatus.HEALTHY


class FailureDetector:
    def __init__(self, node_ids: List[str], *, suspect_after_s: float = 1.0,
                 dead_after_s: float = 3.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.suspect_after_s = suspect_after_s
        self.dead_after_s = dead_after_s
        now = clock()
        self.nodes: Dict[str, _Node] = {n: _Node(now) for n in node_ids}

    def heartbeat(self, node: str) -> None:
        n = self.nodes[node]
        n.last_beat = self.clock()
        n.status = NodeStatus.HEALTHY

    def sweep(self) -> Dict[str, NodeStatus]:
        now = self.clock()
        for n in self.nodes.values():
            dt = now - n.last_beat
            if dt >= self.dead_after_s:
                n.status = NodeStatus.DEAD
            elif dt >= self.suspect_after_s:
                n.status = NodeStatus.SUSPECT
        return {k: v.status for k, v in self.nodes.items()}

    def alive(self) -> List[str]:
        self.sweep()
        return [k for k, v in self.nodes.items()
                if v.status != NodeStatus.DEAD]

    def dead(self) -> List[str]:
        self.sweep()
        return [k for k, v in self.nodes.items()
                if v.status == NodeStatus.DEAD]
