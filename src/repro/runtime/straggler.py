"""Deadline-based straggler mitigation.

Tracks a robust moving estimate of step time; steps exceeding
``deadline_factor`` x median are flagged.  The trainer's response is
backup-dispatch or skip-with-accumulation: a flagged microbatch's
gradient contribution is dropped this step and the accumulation count
raised next step, so the optimizer statistics stay unbiased.
"""
from __future__ import annotations

import collections
import statistics
from typing import Deque, Optional


class StragglerMitigator:
    def __init__(self, *, window: int = 32, deadline_factor: float = 2.0):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.deadline_factor = deadline_factor
        self.flagged = 0
        self.catchup_pending = 0

    def observe(self, step_time_s: float) -> bool:
        """Record a step time; returns True when it breached the deadline."""
        deadline = self.deadline()
        self.window.append(step_time_s)
        if deadline is not None and step_time_s > deadline:
            self.flagged += 1
            self.catchup_pending += 1
            return True
        return False

    def deadline(self) -> Optional[float]:
        if len(self.window) < 8:
            return None
        return statistics.median(self.window) * self.deadline_factor

    def take_catchup(self) -> int:
        """Microbatches to add to the next accumulation round."""
        n, self.catchup_pending = self.catchup_pending, 0
        return n
