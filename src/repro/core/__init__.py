"""PCS core: the paper's contribution (Persistent CXL Switch).

Two coupled layers:
  * ``semantics`` — the exact PB/PBC/PBCS state machine (correctness
    oracle; also reused by the cluster persistence tier).
  * ``simulator`` — the timed, jit/vmap-able queueing simulator that
    replaces the paper's gem5 evaluation.
"""
from repro.core.params import (LatencyProfile, Op, PBEState, PCSConfig,
                               Scheme)
from repro.core.semantics import (Event, EventKind, PersistentBuffer,
                                  PersistentMemory)
from repro.core.simulator import SimResult, simulate, simulate_sweep
from repro.core.traces import Trace, WORKLOADS, make_trace

__all__ = [
    "LatencyProfile", "Op", "PBEState", "PCSConfig", "Scheme",
    "Event", "EventKind", "PersistentBuffer", "PersistentMemory",
    "SimResult", "simulate", "simulate_sweep",
    "Trace", "WORKLOADS", "make_trace",
]
