"""PCS core: the paper's contribution (Persistent CXL Switch).

Coupled layers (DESIGN.md §2):
  * ``semantics`` — the exact PB/PBC/PBCS state machine (correctness
    oracle; also reused by the cluster persistence tier).
  * ``engine``    — the timed, jit/vmap-able queueing engine that
    replaces the paper's gem5 evaluation; ``simulate_grid`` runs the
    whole {trace x config x scheme} grid as one XLA program.  Both read
    their drain-policy definitions from ``engine.policy``.
"""
from repro.core.engine import (SimResult, simulate, simulate_grid,
                               simulate_sweep)
from repro.core.params import (AllocPolicy, DrainPolicy, FabricTopology,
                               LatencyProfile, Op, PBEState, PBPolicy,
                               PCSConfig, Schedule, Scheme)
from repro.core.semantics import (Event, EventKind, PersistentBuffer,
                                  PersistentMemory)
from repro.core.traces import (BurstyArrivals, DiurnalArrivals,
                               PoissonArrivals, Trace, WORKLOADS,
                               apply_arrivals, compose_tenants,
                               fuzz_crash_ns, fuzz_trace, leaf_placement,
                               make_mixed_tenant_trace,
                               make_offered_load_trace, make_tenant_trace,
                               make_trace, tenant_ids)

__all__ = [
    "AllocPolicy", "DrainPolicy", "FabricTopology", "LatencyProfile",
    "Op", "PBEState", "PBPolicy", "PCSConfig", "Schedule", "Scheme",
    "Event", "EventKind", "PersistentBuffer", "PersistentMemory",
    "SimResult", "simulate", "simulate_grid", "simulate_sweep",
    "BurstyArrivals", "DiurnalArrivals", "PoissonArrivals",
    "Trace", "WORKLOADS", "apply_arrivals", "compose_tenants",
    "fuzz_crash_ns", "fuzz_trace", "leaf_placement",
    "make_mixed_tenant_trace", "make_offered_load_trace",
    "make_tenant_trace", "make_trace", "tenant_ids",
]
