"""Splash-4-analogue trace generators (Section VI, Table II).

The paper evaluates seven Splash-4 benchmarks under the "efficient
checkpointing" persist discipline (every heap store is made durable with
clflush+mfence at loop-iteration granularity) with a 100k-persist ROI cap.
The binaries are not available offline, so each generator below emits the
LLC-miss-level memory-request stream *derived from the algorithm's loop
nest* (FFT, blocked LU) or from its published locality signature
(Cholesky/Radiosity/Raytrace/Volrend), at 64-byte line granularity.

Per-workload calibration targets (paper Figs. 5-7):
    workload     write-locality  read-after-persist  expected PB_RF
    radiosity    very high       ~51% hit            big win
    lu_cont      moderate        ~20% hit            win
    lu_non       moderate        ~20% hit            win (>20% PB)
    raytrace     moderate        ~20% hit            win
    fft          low (2.8%)      ~20% hit            small win / RF loss
    cholesky     ~1%             ~1% hit             slowdown
    volrend_npl  ~1%             ~1% hit             mild slowdown

Each trace is a per-core sequence of (op, addr, gap) where `gap` is the ns
of computation preceding the op.  An LRU filter models the private-L1 +
shared-L2 hierarchy (Table I: 32KB L1 / 256KB L2 -> ~4K lines visible per
core); persists always traverse to the switch (clflush forces write-back).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.params import Op

# Heap (persistent) lines live below this boundary; volatile above it.
PM_REGION_LINES = 1 << 22
DRAM_BASE = 1 << 24

# Paper ROI budget: "up-to 100,000 write operations to PM" (all cores).
DEFAULT_PERSIST_BUDGET = 100_000


class LLCFilter:
    """LRU filter approximating the per-core view of the cache hierarchy."""

    def __init__(self, capacity_lines: int = 4096):
        self.capacity = capacity_lines
        self._lru: "OrderedDict[int, None]" = OrderedDict()

    def access(self, line: int) -> bool:
        """Returns True when the access misses (must go to memory)."""
        if line in self._lru:
            self._lru.move_to_end(line)
            return False
        self._lru[line] = None
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
        return True

    def invalidate(self, line: int) -> None:
        self._lru.pop(line, None)


@dataclasses.dataclass
class Trace:
    """Padded per-core trace arrays consumed by the timed simulator."""

    ops: np.ndarray      # (C, L) int32
    addrs: np.ndarray    # (C, L) int32
    gaps: np.ndarray     # (C, L) float32 — compute ns preceding the op
    lengths: np.ndarray  # (C,) int32
    name: str = ""

    @property
    def n_cores(self) -> int:
        return self.ops.shape[0]

    @property
    def total_ops(self) -> int:
        return int(self.lengths.sum())

    def counts(self) -> Dict[str, int]:
        out = {}
        for op in Op:
            n = 0
            for c in range(self.n_cores):
                n += int((self.ops[c, : self.lengths[c]] == int(op)).sum())
            out[op.name.lower()] = n
        return out


class _CoreStream:
    """Builder for one core's op stream with an LLC filter attached."""

    def __init__(self, llc_lines: int = 4096):
        self.ops: List[int] = []
        self.addrs: List[int] = []
        self.gaps: List[float] = []
        self._pending_gap = 0.0
        self.llc = LLCFilter(llc_lines)
        self.persists = 0

    def compute(self, ns: float) -> None:
        self._pending_gap += ns

    def _emit(self, op: Op, addr: int) -> None:
        self.ops.append(int(op))
        self.addrs.append(int(addr))
        self.gaps.append(self._pending_gap)
        self._pending_gap = 0.0

    def read_pm(self, line: int) -> None:
        if self.llc.access(line):
            self._emit(Op.PM_READ, line)
        else:
            self.compute(1.0)  # L1/L2 hit cost

    def persist(self, line: int) -> None:
        # clflush evicts the line from the hierarchy and pushes it to PM.
        self.llc.invalidate(line)
        self._emit(Op.PERSIST, line)
        self.persists += 1

    def barrier(self) -> None:
        self._emit(Op.BARRIER, 0)

    def read_dram(self, line: int) -> None:
        if self.llc.access(DRAM_BASE + line):
            self._emit(Op.DRAM_READ, DRAM_BASE + line)
        else:
            self.compute(1.0)

    def write_dram(self, line: int) -> None:
        if self.llc.access(DRAM_BASE + line):
            self._emit(Op.DRAM_WRITE, DRAM_BASE + line)
        else:
            self.compute(1.0)


def _pack(streams: List[_CoreStream], name: str,
          barrier_groups: "List[range] | None" = None) -> Trace:
    # Barriers must be consistent across the cores that share them (one
    # group per tenant; barriers are tenant-local) or the simulation
    # deadlocks.
    groups = barrier_groups or [range(len(streams))]
    for g in groups:
        bar_counts = {sum(1 for o in streams[c].ops if o == int(Op.BARRIER))
                      for c in g}
        if len(bar_counts) > 1:
            raise ValueError(
                f"inconsistent barrier counts in {name}{list(g)}: "
                f"{bar_counts}")
    lengths = np.array([len(s.ops) for s in streams], dtype=np.int32)
    L = int(lengths.max()) if len(streams) else 0
    C = len(streams)
    ops = np.zeros((C, L), dtype=np.int32)
    addrs = np.zeros((C, L), dtype=np.int32)
    gaps = np.zeros((C, L), dtype=np.float32)
    for c, s in enumerate(streams):
        n = lengths[c]
        ops[c, :n] = s.ops
        addrs[c, :n] = s.addrs
        gaps[c, :n] = s.gaps
    return Trace(ops=ops, addrs=addrs, gaps=gaps, lengths=lengths, name=name)


def plan_runs(ops: np.ndarray, addrs: np.ndarray, gaps: np.ndarray,
              kmax: int = None) -> np.ndarray:
    """Trace-time macro-run planner (numpy pre-pass for engine.macro).

    ``mlen[c, i]`` is the length (1..kmax) of the longest *statically
    eligible* homogeneous run starting at op ``i`` of core ``c``: every
    op in the window is a PM_READ or PERSIST with a non-negative compute
    gap, and no two ops in the window share an address when either of
    the pair is a PERSIST (same-address pairs would coalesce in the PB /
    hit in the read path, which the engine's unrolled macro-step guards
    against dynamically anyway — the static filter just avoids paying
    for windows that would always abort).

    The value is only a *candidate*: the engine still evaluates its
    traced guard set (no cross-core interleaving, crash outside the
    window, depth-1, no PB hits, a free slot for every persist, ...) and
    falls back to slot-at-a-time handlers when any guard fails, so
    results are bit-exact by construction whether or not a run commits.

    Prefixes of eligible windows are eligible (the recurrence below is
    an all-pairs induction), so the engine may truncate a run at the
    stream tail without re-planning.
    """
    if kmax is None:
        from repro.core.params import MACRO_KMAX
        kmax = MACRO_KMAX
    ops = np.asarray(ops)
    addrs = np.asarray(addrs)
    gaps = np.asarray(gaps)
    C, L = ops.shape
    is_p = ops == int(Op.PERSIST)
    valid = (is_p | (ops == int(Op.PM_READ))) & (gaps >= 0.0)
    mlen = np.ones((C, L), np.int8)
    for K in range(2, kmax + 1):
        d = K - 1
        if d >= L:
            break
        # valid_K[i] = valid_{K-1}[i] & valid_{K-1}[i+1] & pair_ok(i, i+d)
        pair_ok = ~((addrs[:, :L - d] == addrs[:, d:])
                    & (is_p[:, :L - d] | is_p[:, d:]))
        v_next = np.zeros((C, L), bool)
        v_next[:, :L - d] = valid[:, :L - d] & valid[:, 1:L - d + 1] & pair_ok
        if not v_next.any():
            break
        mlen[v_next] = K
        valid = v_next
    return mlen


# ===========================================================================
# Algorithm-derived generators
# ===========================================================================

def fft_trace(n_cores: int = 8, m: int = 12, seed: int = 0,
              persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    """Radix-2 FFT, -m12 (2^12 complex doubles), Splash-4 FFT kernel.

    Each of the log2(n) stages touches every point once; points are 16B so
    4 points share a line.  Following the efficient-checkpointing persist
    discipline, each core flushes the lines it modified at the end of every
    EPOCH butterflies (once per line per epoch), then all cores barrier at
    the stage boundary.  A line is re-persisted only one full stage later,
    giving FFT its low write-coalescing rate (~3%).  The inter-core
    exchange of the six-step FFT is modeled by each core reading two lines
    of its neighbour's just-flushed epoch — the read-after-persist traffic
    behind FFT's moderate RF hit rate and its PB read-latency increase.
    """
    del seed  # deterministic address stream
    n = 1 << m
    points_per_line = 4
    streams = [_CoreStream() for _ in range(n_cores)]
    budget = persist_budget
    epoch = 8  # butterflies between checkpoint flushes

    for stage in range(m):
        half = 1 << stage
        # pass 1: per-core epoch flush lists (address math only)
        flushes: List[List[List[int]]] = []
        spans = []
        for c in range(n_cores):
            lo = (n // 2) * c // n_cores
            hi = (n // 2) * (c + 1) // n_cores
            spans.append((lo, hi))
            eps: List[List[int]] = []
            dirty: "OrderedDict[int, None]" = OrderedDict()
            for j, b in enumerate(range(lo, hi)):
                top = (b // half) * (2 * half) + (b % half)
                bot = top + half
                dirty[top // points_per_line] = None
                dirty[bot // points_per_line] = None
                if (j + 1 + 3 * c) % epoch == 0:
                    eps.append(list(dirty))
                    dirty.clear()
            if dirty:
                eps.append(list(dirty))
            flushes.append(eps)
        # pass 2: emit ops; core c reads 2 lines of core c-1's same epoch
        for c in range(n_cores):
            s = streams[c]
            lo, hi = spans[c]
            e_idx = 0
            for j, b in enumerate(range(lo, hi)):
                top = (b // half) * (2 * half) + (b % half)
                bot = top + half
                l_top, l_bot = top // points_per_line, bot // points_per_line
                s.read_pm(l_top)
                if l_bot != l_top:
                    s.read_pm(l_bot)
                s.compute(3800.0)  # flops, twiddles, transposes, sync slack
                if (j + 1 + 3 * c) % epoch == 0 or b == hi - 1:
                    for ln in flushes[c][e_idx]:
                        if budget > 0:
                            s.persist(ln)
                            budget -= 1
                        s.compute(3.0)
                    # neighbour-boundary exchange reads
                    prev = flushes[(c - 1) % n_cores]
                    if e_idx < len(prev) and prev[e_idx]:
                        for ln in prev[e_idx][:2]:
                            s.read_pm(ln)
                    e_idx += 1
        for s in streams:
            s.barrier()
    return _pack(streams, "fft")


def _lu_trace(n_cores: int, n: int, block: int, contiguous: bool,
              seed: int, persist_budget: int, name: str) -> Trace:
    """Blocked right-looking LU, -n128 (Splash-4 LU kernel).

    Contiguous: blocks are stored contiguously (a 16x16 double block = 32
    consecutive lines).  Non-contiguous: row-major full matrix, so a block
    row (16 doubles = 128B) spans 2 lines and rows stride 16 lines, halving
    line-level write reuse — which is why Lu_non benefits more from the PB.

    Phases are separated by barriers (as in Splash-4): the owner factors
    and persists the pivot block, then every core's panel update re-reads
    the freshly flushed pivot lines — the cross-core read-after-persist
    pattern behind LU's ~20% RF hit rate.

    ``seed`` jitters the dgemm compute gaps (exponential multiplier,
    the same idiom as :func:`_signature_trace`), so ``lu_cont`` (seed 1)
    and ``lu_non`` (seed 2) genuinely differ in timing; the op/address
    stream itself is the deterministic loop nest.
    """
    rng = np.random.default_rng(seed)
    nb = n // block
    elems_per_line = 8
    streams = [_CoreStream() for _ in range(n_cores)]
    budget = persist_budget

    def block_lines(bi: int, bj: int) -> np.ndarray:
        if contiguous:
            base = (bi * nb + bj) * (block * block // elems_per_line)
            return np.arange(base, base + block * block // elems_per_line)
        # row-major n x n matrix of doubles
        rows = bi * block + np.arange(block)
        start = rows * (n // elems_per_line) + (bj * block) // elems_per_line
        width = max(block // elems_per_line, 1)  # lines per block row
        return (start[:, None] + np.arange(width)[None, :]).ravel()

    def persist_block(s: _CoreStream, lines: np.ndarray,
                      repeat: int = 1, group_sz: int = 2) -> None:
        # `repeat` models element-granularity flushing: clflush evicts the
        # line, the next element write re-fetches it (an RFO read that the
        # PB can serve — LU's RF hit source) and flushes it again while the
        # previous version is still Dirty (LU's coalescing source).
        nonlocal budget
        for group in np.array_split(lines, max(len(lines) // group_sz, 1)):
            for _ in range(repeat):
                for ln in group:
                    s.read_pm(int(ln))
                    s.compute(30.0)
                    if budget > 0:
                        s.persist(int(ln))
                        budget -= 1

    for k in range(nb):
        # 1. factor the diagonal block (owner core persists it)
        owner = k % n_cores
        persist_block(streams[owner], block_lines(k, k),
                      repeat=1 if contiguous else 2)
        for s in streams:
            s.barrier()
        # 2. panel updates: every panel task re-reads the pivot block
        panels = [(k, j) for j in range(k + 1, nb)] + \
                 [(i, k) for i in range(k + 1, nb)]
        for p_idx, (bi, bj) in enumerate(panels):
            s = streams[p_idx % n_cores]
            for ln in block_lines(k, k):      # freshly persisted pivot
                s.read_pm(int(ln))
                s.compute(4.0)
            persist_block(s, block_lines(bi, bj),
                          repeat=1 if contiguous else 2)
        for s in streams:
            s.barrier()
        # 3. trailing submatrix update (owner-computes by column block)
        trailing = [(i, j) for i in range(k + 1, nb) for j in range(k + 1, nb)]
        for t_i, (bi, bj) in enumerate(trailing):
            s = streams[bj % n_cores]
            s.compute((2800.0 if contiguous else 1500.0)
                      * float(rng.exponential(1.0)))  # dgemm arithmetic
            for ln in block_lines(bi, k):
                s.read_pm(int(ln))
            for ln in block_lines(k, bj):
                s.read_pm(int(ln))
            persist_block(s, block_lines(bi, bj),
                          repeat=2 if (t_i % 4 == 0 or not contiguous) else 1)
        for s in streams:
            s.barrier()
        if budget <= 0:
            break
    return _pack(streams, name)


def lu_cont_trace(n_cores: int = 8, seed: int = 1,
                  persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    return _lu_trace(n_cores, 128, 16, True, seed, persist_budget, "lu_cont")


def lu_non_trace(n_cores: int = 8, seed: int = 2,
                 persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    return _lu_trace(n_cores, 128, 16, False, seed, persist_budget, "lu_non")


# ===========================================================================
# Signature-derived generators
# ===========================================================================

def _signature_trace(name: str, n_cores: int, seed: int, *,
                     n_iters: int,
                     hot_lines: int,
                     cold_lines: int,
                     p_persist: float,
                     p_hot_write: float,
                     reads_per_iter: float,
                     p_read_recent: float,
                     compute_ns: float,
                     persist_budget: int,
                     recent_window: int = 8,
                     zipf_a: float = 1.4,
                     persist_burst: int = 1,
                     p_read_mid: float = 0.0,
                     mid_window: int = 256,
                     p_shared: float = 1.0,
                     recent_global: bool = False) -> Trace:
    """Stochastic generator parameterized by a workload's locality signature.

    p_hot_write    — probability a persist targets the small hot set, with
                     Zipf(zipf_a) concentration within it (drives the
                     write-coalescing rate of Fig 7b: a re-persist coalesces
                     only while the line is still Dirty in the 16-entry PB).
    p_read_recent  — probability a PM read targets one of the
                     `recent_window` most recently persisted lines on the
                     same core (the persist-A-then-load-A pattern of Fig 2;
                     drives the RF read-hit rate of Fig 7a).
    p_read_mid     — reads to mid-distance persisted lines (drained and
                     evicted from the 16-entry PB long ago; they go straight
                     to PM but land in the PM-channel shadow of drain
                     bursts — the Cholesky read-latency mechanism).
    p_shared       — fraction of hot persists to globally shared lines;
                     the rest hit a per-core partition of the hot set
                     (radiosity partitions patches among workers, so most
                     re-persists of a line come from one core).
    persist_burst  — lines persisted back-to-back (e.g. a sparse-Cholesky
                     column flush), which makes drain traffic bursty.
    """
    rng = np.random.default_rng(seed)
    streams = [_CoreStream() for _ in range(n_cores)]
    budget = persist_budget
    # recency: per-core (a core re-reads its own fresh writes) or global
    # (consumers chase other cores' freshly persisted data, e.g. the
    # left-looking Cholesky dependency pattern)
    shared_recent: List[int] = []
    recent: List[List[int]] = [shared_recent] * n_cores if recent_global \
        else [[] for _ in range(n_cores)]
    mid: List[int] = []  # global mid-distance window
    # Zipf ranks over the hot set, precomputed for sampling
    ranks = np.arange(1, hot_lines + 1, dtype=np.float64)
    zipf_p = ranks ** (-zipf_a)
    zipf_p /= zipf_p.sum()
    next_cold = hot_lines  # fresh cold lines for write-once streams

    slice_sz = max(hot_lines // n_cores, 1)

    def pick_persist_line(c: int) -> int:
        nonlocal next_cold
        if rng.random() < p_hot_write:
            z = int(rng.choice(hot_lines, p=zipf_p))
            if rng.random() < p_shared:
                return z
            return (c * slice_sz + z % slice_sz) % hot_lines
        next_cold += 1
        return hot_lines + (next_cold % cold_lines)

    for _ in range(n_iters):
        if budget <= 0:
            break
        for c in range(n_cores):
            s = streams[c]
            s.compute(compute_ns * float(rng.exponential(1.0)))
            # reads
            n_reads = rng.poisson(reads_per_iter)
            for _ in range(n_reads):
                r = recent[c]
                u = rng.random()
                if r and u < p_read_recent:
                    line = r[rng.integers(len(r))]
                elif mid and u < p_read_recent + p_read_mid:
                    line = mid[rng.integers(len(mid))]
                else:
                    line = hot_lines + int(rng.integers(cold_lines))
                s.read_pm(line)
            # persist burst
            if rng.random() < p_persist and budget > 0:
                for _ in range(persist_burst):
                    if budget <= 0:
                        break
                    line = pick_persist_line(c)
                    s.persist(line)
                    budget -= 1
                    recent[c].append(line)
                    if len(recent[c]) > recent_window:
                        mid.append(recent[c].pop(0))
                        if len(mid) > mid_window:
                            mid.pop(0)
    return _pack(streams, name)


def cholesky_trace(n_cores: int = 8, seed: int = 3,
                   persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    """Sparse left-looking Cholesky (tk18.O): read-dominated; each column
    is written once (coalescing ~1%) and read long after it was drained
    (RF hit ~1%), so PB's PI-buffer read detour costs dominate."""
    return _signature_trace(
        "cholesky", n_cores, seed,
        n_iters=5200, hot_lines=32, cold_lines=200_000,
        p_persist=0.030, p_hot_write=0.01,
        reads_per_iter=9.0, p_read_recent=0.10,
        compute_ns=150.0, persist_budget=persist_budget,
        recent_window=12, persist_burst=32,
        p_read_mid=0.25, mid_window=256, recent_global=True)


def radiosity_trace(n_cores: int = 8, seed: int = 4,
                    persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    """Radiosity (-ae 5000 -bf 0.1): the interaction loop re-persists a
    small set of patch accumulators at high frequency (coalescing ~50%)
    and immediately re-reads them (RF hit ~51%) — the paper's best case."""
    return _signature_trace(
        "radiosity", n_cores, seed,
        n_iters=4200, hot_lines=18, cold_lines=40_000,
        p_persist=0.85, p_hot_write=0.82,
        reads_per_iter=1.1, p_read_recent=0.75,
        compute_ns=240.0, persist_budget=persist_budget,
        recent_window=4, zipf_a=1.5, p_shared=0.3)


def raytrace_trace(n_cores: int = 8, seed: int = 5,
                   persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    """Raytrace (teapot.env): BVH reads with moderate reuse; irradiance /
    pixel accumulators give ~20% write locality and read-after-persist."""
    return _signature_trace(
        "raytrace", n_cores, seed,
        n_iters=4400, hot_lines=64, cold_lines=60_000,
        p_persist=0.45, p_hot_write=0.32,
        reads_per_iter=2.0, p_read_recent=0.30,
        compute_ns=120.0, persist_budget=persist_budget,
        recent_window=8)


def volrend_trace(n_cores: int = 8, seed: int = 6,
                  persist_budget: int = DEFAULT_PERSIST_BUDGET) -> Trace:
    """Volrend_npl (headscaleddown2): ray-cast reads over a large volume
    (low reuse); image writes are write-once (coalescing/hit ~1%)."""
    return _signature_trace(
        "volrend_npl", n_cores, seed,
        n_iters=4200, hot_lines=32, cold_lines=150_000,
        p_persist=0.025, p_hot_write=0.02,
        reads_per_iter=8.0, p_read_recent=0.06,
        compute_ns=140.0, persist_budget=persist_budget,
        recent_window=12, persist_burst=24,
        p_read_mid=0.22, mid_window=256, recent_global=True)


# ===========================================================================
# Multi-tenant composition (shared-switch scale-out)
# ===========================================================================

def tenant_ids(lengths, n_tenants: int) -> np.ndarray:
    """Per-core tenant ids: the numpy twin of the engine's mapping.

    The timed engine partitions the live cores into ``n_tenants``
    contiguous balanced groups — core ``c`` belongs to tenant
    ``floor(c * T / n_live)`` (``engine.step.scan_cell``).  Tests and
    the oracle driver must use THIS function rather than restating the
    formula, so the two layers cannot drift.
    """
    lengths = np.asarray(lengths)
    n_live = max(int((lengths > 0).sum()), 1)
    tid = (np.arange(len(lengths)) * int(n_tenants)) // n_live
    return np.minimum(tid, n_tenants - 1).astype(np.int32)


def leaf_placement(n_tenants: int, n_leaves: int,
                   mode: str = "packed") -> tuple:
    """Tenant -> leaf placement vector for a fan-out fabric.

    ``"packed"`` fills leaves with contiguous balanced tenant blocks
    (tenant ``t`` on leaf ``floor(t * n_leaves / n_tenants)``) —
    neighbours share a leaf switch, maximizing per-leaf contention and
    leaving far leaves idle.  ``"spread"`` round-robins tenants across
    the leaves — per-leaf load is even, spine fan-in pressure is
    maximal.  The two are the benchmark sweep's placement axis
    (``benchmarks/fig_fabric.py``); both are valid
    ``FabricTopology.placement`` values for any ``n_tenants >=
    n_leaves`` and degenerate to all-zeros at one leaf.
    """
    if n_tenants < 1 or n_leaves < 1:
        raise ValueError("leaf_placement wants n_tenants, n_leaves >= 1")
    if mode == "packed":
        return tuple((t * n_leaves) // n_tenants
                     for t in range(n_tenants))
    if mode == "spread":
        return tuple(t % n_leaves for t in range(n_tenants))
    raise ValueError(f"unknown placement mode: {mode!r}")


def compose_tenants(tenant_traces: List[Trace], *,
                    addr_stride: int | None = None,
                    shared_lines: int = 0,
                    name: str = "") -> Trace:
    """Stack per-tenant workload traces into one shared-switch trace.

    Each input trace is one tenant (an independent host); their cores
    are concatenated so the engine's balanced partition maps tenant
    ``t`` exactly onto input ``t`` (every tenant must contribute the
    same number of cores, all live).  PM addresses are relocated into
    disjoint per-tenant windows of ``addr_stride`` lines — independent
    address spaces — except the first ``shared_lines`` lines, which
    stay common to every tenant (the shared-hot-set contention
    variant).  DRAM addresses are host-private state and irrelevant to
    the shared switch; they are left untouched.

    Simulate the result with ``PCSConfig(n_tenants=len(tenant_traces),
    n_cores=<total cores>)``.
    """
    if not tenant_traces:
        raise ValueError("need at least one tenant trace")
    cores = {t.ops.shape[0] for t in tenant_traces}
    if len(cores) != 1:
        raise ValueError(
            "tenants must contribute equal core counts so the engine's "
            f"balanced partition lands on tenant boundaries; got {cores}")
    for t in tenant_traces:
        if np.any(t.lengths <= 0):
            raise ValueError(
                f"every core must be live (non-empty stream); {t.name!r} "
                "has an empty core, which would shift the partition")
    T = len(tenant_traces)
    pm_max = 0
    for t in tenant_traces:
        pm = (t.addrs < DRAM_BASE) & np.isin(
            t.ops, (int(Op.PM_READ), int(Op.PERSIST)))
        if np.any(pm):
            pm_max = max(pm_max, int(t.addrs[pm].max()) + 1)
    if addr_stride is None:
        addr_stride = max(pm_max, shared_lines + 1)
    elif addr_stride < pm_max:
        # a narrower stride would relocate different tenants onto the
        # same PM lines — silently breaking the promised disjointness
        raise ValueError(
            f"addr_stride={addr_stride} is smaller than the tenants' PM "
            f"footprint ({pm_max} lines): per-tenant windows would overlap")
    if not 0 <= shared_lines <= addr_stride:
        raise ValueError("require 0 <= shared_lines <= addr_stride")
    if shared_lines + T * (addr_stride - shared_lines) > PM_REGION_LINES:
        raise ValueError("tenant address windows exceed the PM region; "
                         "lower addr_stride or the tenant count")
    C = cores.pop()
    L = max(t.ops.shape[1] for t in tenant_traces)
    ops = np.zeros((T * C, L), np.int32)
    addrs = np.zeros((T * C, L), np.int32)
    gaps = np.zeros((T * C, L), np.float32)
    lengths = np.zeros((T * C,), np.int32)
    for t, tr in enumerate(tenant_traces):
        lo, l = t * C, tr.ops.shape[1]
        ops[lo:lo + C, :l] = tr.ops
        gaps[lo:lo + C, :l] = tr.gaps
        lengths[lo:lo + C] = tr.lengths
        a = tr.addrs.astype(np.int64)
        private = ((a < DRAM_BASE) & (a >= shared_lines)
                   & np.isin(tr.ops, (int(Op.PM_READ), int(Op.PERSIST))))
        a = np.where(private, a + t * (addr_stride - shared_lines), a)
        addrs[lo:lo + C, :l] = a[:, :l].astype(np.int32)
    name = name or ("+".join(t.name for t in tenant_traces) or "tenants")
    return Trace(ops=ops, addrs=addrs, gaps=gaps, lengths=lengths,
                 name=f"{name}[T={T}]")


def make_mixed_tenant_trace(specs: "List[Tuple[str, int]]",
                            cores_per_tenant: int = 2, *,
                            shared_lines: int = 0, seed: int = 0,
                            name: str = "", **kw) -> Trace:
    """Heterogeneous tenants on one shared switch — the quota-pressure
    composition behind the QoS policy sweeps.

    ``specs`` is one ``(workload, persist_budget)`` pair per tenant, so
    a *noisy* tenant (large budget, write-hot workload) can sit next to
    quiet ones: without per-tenant PBE quotas the noisy tenant's
    allocations and drain-downs monopolize the shared PB, which is
    exactly the skew ``benchmarks/fig_qos.py`` sweeps policies against.
    Each tenant gets a distinct seed (distinct streams) and the usual
    disjoint PM address window (``shared_lines`` keeps a common hot
    window, see :func:`compose_tenants`).
    """
    if not specs:
        raise ValueError("need at least one (workload, budget) spec")
    parts = [make_trace(w, n_cores=cores_per_tenant, seed=seed + 101 * t,
                        persist_budget=budget, **kw)
             for t, (w, budget) in enumerate(specs)]
    name = name or "+".join(f"{w}@{b}" for w, b in specs)
    return compose_tenants(parts, shared_lines=shared_lines, name=name)


def make_tenant_trace(workload: str, n_tenants: int,
                      cores_per_tenant: int = 2, *,
                      shared_lines: int = 0, seed: int = 0,
                      persist_budget: int = DEFAULT_PERSIST_BUDGET,
                      **kw) -> Trace:
    """``n_tenants`` independent instances of one workload on a shared
    switch: each tenant runs its own ``cores_per_tenant``-core copy
    (distinct seed, so distinct streams) with ``persist_budget`` persists
    *per tenant* — offered load scales with the tenant count, which is
    the scale-out contention axis of the tenant sweep."""
    parts = [make_trace(workload, n_cores=cores_per_tenant,
                        seed=seed + 101 * t, persist_budget=persist_budget,
                        **kw)
             for t in range(n_tenants)]
    return compose_tenants(parts, shared_lines=shared_lines,
                           name=workload)


# ===========================================================================
# Fuzzed conformance traces (crash-differential harness)
# ===========================================================================

# Slot spacing of fuzzed traces.  Each op occupies one global "slot" at
# nominal time slot*FUZZ_SLOT_GAP_NS; the gap dwarfs every service
# latency (persist ack, victim wait, drain burst are all < ~5 us), so
# (a) the engine's issue-time merge executes ops exactly in slot order,
# (b) every drain scheduled by slot k's op is acked before slot k+1
#     (the oracle's prompt-ack regime), and
# (c) a crash at fuzz_crash_ns(k) falls cleanly *between* slot k and
#     slot k+1 — the same logical point in both layers.
FUZZ_SLOT_GAP_NS = 1.0e6
# A core's clock drifts past its nominal slot time by the accumulated
# service latencies of its own ops (< ~1 us each in the uncongested
# regime); the slot-order and crash-boundary guarantees need the total
# drift to stay well under half a slot gap.
_FUZZ_MAX_SLOTS = 250


def fuzz_crash_ns(slot: int, slot_gap_ns: float = FUZZ_SLOT_GAP_NS) -> float:
    """Power-loss instant falling between slot ``slot`` and ``slot + 1``."""
    return (slot + 0.5) * slot_gap_ns


def fuzz_trace(seed: int, n_cores: int = 3, n_slots: int = 60,
               n_addrs: int = 8, p_persist: float = 0.55,
               p_barrier: float = 0.05,
               slot_gap_ns: float = FUZZ_SLOT_GAP_NS,
               n_tenants: int = 1
               ) -> Tuple[Trace, List[Tuple[int, int, int, int]]]:
    """Random multi-core persist/read/barrier interleaving for the
    crash-differential harness (beyond the 7 paper workloads).

    Returns ``(trace, schedule)`` where ``schedule`` is the global op
    order ``[(slot, core, op, addr), ...]``: the sequence the untimed
    oracle replays, and provably the order the timed engine executes
    (see ``FUZZ_SLOT_GAP_NS``).  Barriers occupy one slot per arriving
    core (consecutive, core order); persist/read slots go to a random
    core.  With ``n_tenants > 1`` the cores split into contiguous
    equal groups and a barrier event synchronizes ONE tenant's cores
    (matching the engine's per-tenant barriers); every tenant's first
    slots are round-robin ops so all cores are live and the engine's
    balanced partition maps group ``t`` to tenant ``t`` exactly.
    """
    if n_slots > _FUZZ_MAX_SLOTS:
        raise ValueError(f"n_slots > {_FUZZ_MAX_SLOTS} breaks the "
                         "slot-order guarantee (clock drift)")
    if n_cores % n_tenants != 0:
        raise ValueError("n_cores must divide evenly into n_tenants")
    cpt = n_cores // n_tenants     # cores per tenant
    rng = np.random.default_rng(seed)
    streams = [_CoreStream() for _ in range(n_cores)]
    nominal = [0] * n_cores        # last issue slot per core
    schedule: List[Tuple[int, int, int, int]] = []
    slot = 1
    # liveness preamble: one op per core, so lengths > 0 everywhere and
    # tenant_ids() is the identity partition on core groups
    warmup = list(range(n_cores)) if n_tenants > 1 else []
    while slot <= n_slots:
        if warmup:
            c = warmup.pop(0)
        elif n_cores > 1 and slot + cpt - 1 <= n_slots \
                and rng.random() < p_barrier:
            # barrier of ONE tenant: its cores arrive at consecutive
            # slots; the last arrival releases them, so each resumes
            # from its tenant's release slot
            t = int(rng.integers(n_tenants))
            for k, c in enumerate(range(t * cpt, (t + 1) * cpt)):
                s = streams[c]
                s.compute((slot + k - nominal[c]) * slot_gap_ns)
                s.barrier()
                schedule.append((slot + k, c, int(Op.BARRIER), 0))
            release = slot + cpt - 1
            for c in range(t * cpt, (t + 1) * cpt):
                nominal[c] = release
            slot += cpt
            continue
        else:
            c = int(rng.integers(n_cores))
        op = Op.PERSIST if rng.random() < p_persist else Op.PM_READ
        addr = int(rng.integers(n_addrs))
        streams[c].compute((slot - nominal[c]) * slot_gap_ns)
        # bypass the LLC filter: conformance traces are switch-level op
        # streams, every op must reach the simulated switch
        streams[c]._emit(op, addr)
        schedule.append((slot, c, int(op), addr))
        nominal[c] = slot
        slot += 1
    groups = [range(t * cpt, (t + 1) * cpt) for t in range(n_tenants)]
    return _pack(streams, f"fuzz{seed}", barrier_groups=groups), schedule


WORKLOADS: Dict[str, Callable[..., Trace]] = {
    "fft": fft_trace,
    "lu_cont": lu_cont_trace,
    "lu_non": lu_non_trace,
    "cholesky": cholesky_trace,
    "radiosity": radiosity_trace,
    "raytrace": raytrace_trace,
    "volrend_npl": volrend_trace,
}


def make_trace(name: str, n_cores: int = 8, **kw) -> Trace:
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; have {sorted(WORKLOADS)}")
    return WORKLOADS[name](n_cores=n_cores, **kw)


# ===========================================================================
# Serving-style offered load (open-loop arrival processes)
# ===========================================================================
# The workload generators above are *closed-loop*: each core computes,
# then issues, so the issue rate adapts to service latency and a
# saturated switch simply slows the workload down.  Serving traffic is
# the opposite — requests arrive at an *offered* rate regardless of how
# the system is doing, and the experienced tail latency explodes at the
# saturation knee.  An :class:`ArrivalProcess` re-times an existing
# workload trace: every compute gap is replaced by an interarrival
# sample ``E * 1000 / rate(t)`` ns with ``E ~ Exp(1)`` and ``rate`` in
# Mops/s per core, evaluated at the core's *nominal* arrival clock (the
# open-loop schedule, independent of service times).  The result is
# semi-open: arrivals pace the think time, but a core still blocks on
# its in-flight persist, so the queue lives in the switch/PM resources
# — exactly where the knee forms as the offered interarrival gap drops
# below the persist service time.  Offered load thereby becomes a
# sweepable *trace* axis of ``simulate_grid``, like ``crash_at_ns`` is
# a config axis.

@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """Open-loop Poisson arrivals at a constant per-core offered load."""

    rate_mops: float                 # million ops/s per core

    def __post_init__(self) -> None:
        if not self.rate_mops > 0:
            raise ValueError("rate_mops must be > 0")

    @property
    def label(self) -> str:
        return f"poisson{self.rate_mops:g}"

    def rate_at(self, t_ns: float) -> float:
        return self.rate_mops

    def sample_gaps(self, n: int, rng: np.random.Generator) -> np.ndarray:
        # constant rate: the sequential loop in _sample_gaps reduces to
        # e[i] * (1000 / rate) elementwise — vectorize it
        return rng.exponential(1.0, n) * (1000.0 / self.rate_mops)


@dataclasses.dataclass(frozen=True)
class BurstyArrivals:
    """On-off (bursty) arrivals: rate ``burst``x higher during the on
    phase, scaled so the *time-average* offered load is ``rate_mops``."""

    rate_mops: float                 # time-average load, Mops/s per core
    burst: float = 8.0               # on-phase / off-phase rate ratio
    on_fraction: float = 0.25        # fraction of each period spent on
    period_ns: float = 200_000.0
    phase_ns: float = 0.0

    def __post_init__(self) -> None:
        if not self.rate_mops > 0:
            raise ValueError("rate_mops must be > 0")
        if not self.burst >= 1.0:
            raise ValueError("burst must be >= 1")
        if not 0.0 < self.on_fraction <= 1.0:
            raise ValueError("on_fraction must be in (0, 1]")
        if not self.period_ns > 0:
            raise ValueError("period_ns must be > 0")

    @property
    def label(self) -> str:
        return f"bursty{self.rate_mops:g}x{self.burst:g}"

    def rate_at(self, t_ns: float) -> float:
        f = self.on_fraction
        # r_on * f + (r_on / burst) * (1 - f) == rate_mops
        r_on = self.rate_mops * self.burst / (f * self.burst + (1.0 - f))
        on = ((t_ns + self.phase_ns) % self.period_ns) < f * self.period_ns
        return r_on if on else r_on / self.burst

    def sample_gaps(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return _sample_gaps(self, n, rng)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals:
    """Sinusoidal rate profile (a compressed day): ``rate_mops * (1 +
    amplitude * sin(2*pi*t/period))``, time-average ``rate_mops``."""

    rate_mops: float                 # time-average load, Mops/s per core
    amplitude: float = 0.5           # peak-to-mean swing, < 1
    period_ns: float = 2_000_000.0
    phase_ns: float = 0.0

    def __post_init__(self) -> None:
        if not self.rate_mops > 0:
            raise ValueError("rate_mops must be > 0")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        if not self.period_ns > 0:
            raise ValueError("period_ns must be > 0")

    @property
    def label(self) -> str:
        return f"diurnal{self.rate_mops:g}a{self.amplitude:g}"

    def rate_at(self, t_ns: float) -> float:
        w = 2.0 * np.pi * (t_ns + self.phase_ns) / self.period_ns
        return self.rate_mops * (1.0 + self.amplitude * float(np.sin(w)))

    def sample_gaps(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return _sample_gaps(self, n, rng)


def _sample_gaps(proc, n: int, rng: np.random.Generator) -> np.ndarray:
    """Sequential interarrival sampling under a time-varying rate: each
    gap is an Exp(1) draw scaled by the instantaneous rate at the
    *nominal* arrival time (the open-loop clock the gaps themselves
    accumulate — service times never feed back into it)."""
    e = rng.exponential(1.0, n)
    out = np.empty((n,), np.float64)
    t = 0.0
    for i in range(n):
        g = e[i] * 1000.0 / proc.rate_at(t)
        out[i] = g
        t += g
    return out


def apply_arrivals(trace: Trace, arrivals, *, seed: int = 0,
                   n_tenants: int = 1) -> Trace:
    """Re-time ``trace`` under open-loop arrival processes.

    Ops, addresses and lengths are untouched — only the compute gaps
    are replaced, per core, by interarrival samples from the core's
    tenant's :class:`ArrivalProcess`.  ``arrivals`` is one process (or
    a bare rate in Mops/s per core, promoted to Poisson) applied to
    every tenant, or a sequence of ``n_tenants`` processes mapped onto
    cores via :func:`tenant_ids` — per-tenant rate profiles on a shared
    switch.  Deterministic in ``seed`` (one substream per core).
    """
    procs = arrivals if isinstance(arrivals, (list, tuple)) else [arrivals]
    procs = [PoissonArrivals(p) if isinstance(p, (int, float)) else p
             for p in procs]
    if len(procs) not in (1, n_tenants):
        raise ValueError(f"need 1 or n_tenants={n_tenants} arrival "
                         f"processes, got {len(procs)}")
    tid = tenant_ids(trace.lengths, n_tenants)
    gaps = np.array(trace.gaps, np.float32, copy=True)
    for c in range(trace.n_cores):
        n = int(trace.lengths[c])
        if n <= 0:
            continue
        rng = np.random.default_rng([seed, c])
        proc = procs[0] if len(procs) == 1 else procs[int(tid[c])]
        gaps[c, :n] = proc.sample_gaps(n, rng).astype(np.float32)
    label = "+".join(p.label for p in procs)
    return Trace(ops=trace.ops, addrs=trace.addrs, gaps=gaps,
                 lengths=trace.lengths, name=f"{trace.name}@{label}")


def make_offered_load_trace(workload: str, arrivals, *, n_cores: int = 8,
                            seed: int = 0,
                            persist_budget: int = DEFAULT_PERSIST_BUDGET,
                            n_tenants: int = 1, **kw) -> Trace:
    """One-call serving composition: build ``workload``'s op/address
    stream, then re-time it under ``arrivals`` (a process, a bare
    Mops/s rate, or one process per tenant) — the offered-load axis of
    ``benchmarks/fig_slo.py``."""
    base = make_trace(workload, n_cores=n_cores,
                      persist_budget=persist_budget, **kw)
    return apply_arrivals(base, arrivals, seed=seed, n_tenants=n_tenants)
