"""Exact (untimed) semantics of the Persistent Buffer state machine.

This module is the *correctness oracle* for the PCS design of Section V:
it implements the PB/PBC/PBCS state machine verbatim — Empty/Dirty/Drain
entry states, LRU victim selection among Dirty entries, the PB scheme's
drain-immediately policy, the PB_RF threshold/preset drain policy, write
coalescing, read forwarding, the write-ack fast path, and the crash /
recovery procedure of Section V-D4.

It is used by:
  * property tests (tests/test_semantics.py, tests/test_recovery.py) that
    check the paper's three correctness criteria under random schedules;
  * the cluster-scale persistence tier (repro.persistence), which runs the
    *same* state machine over checkpoint shards instead of cache lines;
  * cross-validation of the timed JAX engine (repro.core.engine), via
    tests/test_engine_oracle.py.

The model is event-explicit: every externally visible action (ack to the
CPU, drain packet to PM, read response and its source) is returned as an
Event so tests can assert ordering properties.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from repro.core.params import (PBEState, PCSConfig, Scheme, epoch_index,
                               epoch_value, hop_drain_counts, preset_count,
                               resolve_epoch, rf_drain_count,
                               tenant_drain_counts, threshold_count)


class EventKind(enum.Enum):
    PERSIST_ACK = "persist_ack"        # switch acked a persist to the CPU
    DRAIN_SENT = "drain_sent"          # PB emitted a write packet toward PM
    DRAIN_ACKED = "drain_acked"        # PM confirmed a drain (entry freed)
    READ_FROM_PB = "read_from_pb"      # read forwarded from the buffer
    READ_FROM_PM = "read_from_pm"      # read served by the endpoint
    COALESCED = "coalesced"            # write absorbed into a Dirty entry
    STALLED = "stalled"                # PBC had to wait for an Empty entry


@dataclasses.dataclass
class Event:
    kind: EventKind
    addr: int
    version: int
    seq: int  # global monotone sequence number of the event


@dataclasses.dataclass
class PBEntry:
    addr: int
    version: int
    data: object
    state: PBEState
    lru: int  # stamp of last use (higher = more recent)
    tenant: int = 0  # last tenant (host) that wrote this entry
    leaf: int = 0  # owning leaf switch (fan-out fabric; 0 for chains)


class PersistentMemory:
    """The PM endpoint: a versioned store with in-order write application.

    Enforces the paper's *write order* criterion at the device: a write
    carrying an older version than the stored one must never overwrite a
    newer one.  The device accepts writes and produces acks; delivery of
    acks back to the switch is controlled by the caller (tests delay /
    reorder them to probe the protocol).
    """

    def __init__(self) -> None:
        self.store: Dict[int, Tuple[int, object]] = {}
        self.writes_applied = 0

    def write(self, addr: int, version: int, data: object) -> bool:
        """Apply a write; returns False (and drops it) if it is stale."""
        cur = self.store.get(addr)
        if cur is not None and cur[0] > version:
            return False  # stale drain: must not overwrite newer data
        self.store[addr] = (version, data)
        self.writes_applied += 1
        return True

    def read(self, addr: int) -> Optional[Tuple[int, object]]:
        return self.store.get(addr)


class PersistentBuffer:
    """The PB + PBC + PBCS state machine (Section V), untimed.

    Usage protocol (mirrors packet arrival order at the switch):
        ack? = pb.persist(addr, data)    -> list of Events (incl. PERSIST_ACK)
        pb.pm_ack(addr, version)         -> PM write-ack arrived at switch
        src = pb.read(addr)              -> READ_FROM_PB / READ_FROM_PM event
        pb.crash(); pb.recover()         -> Section V-D4

    The NoPB scheme is represented by constructing with scheme=NOPB, in
    which case persists bypass the buffer entirely.
    """

    def __init__(self, config: PCSConfig, pm: Optional[PersistentMemory] = None):
        self.config = config
        # Serving-SLO drain tightening (DrainPolicy.latency_target_ns):
        # the untimed oracle cannot compute persist latencies, so the
        # driver passes a per-persist ``lat_over`` hint; the per-tenant
        # running counters here are the engine's S_PERSIST_CNT /
        # S_SLO_OVER twins, updated at persist *completion* (a stalled
        # packet is counted once, when its retry lands — net of the
        # stall decrement, exactly like the "persists" counter).
        self._slo_cnt: Dict[int, int] = {}
        self.pm = pm if pm is not None else PersistentMemory()
        self.entries: List[PBEntry] = []
        # Switch chain (pooling topologies): ``entries`` is hop 1, the
        # tenant-facing ack point; every deeper switch owns one list in
        # ``hops`` (switch s = ``hops[s - 2]``), with its own capacity
        # and threshold/preset drain counts — the untimed twin of the
        # engine's deep-hop columns.  A hop-1 drain forwards its payload
        # into hop 2 synchronously (:meth:`_forward_batch`); the
        # DRAIN_SENT/pm_ack event protocol is unchanged and models the
        # downstream ack that frees the hop-1 entry.
        self._hop_pbes = config.hop_pbes
        self.n_hops = len(self._hop_pbes)
        self.hops: List[List[PBEntry]] = [
            [] for _ in self._hop_pbes[1:]]
        # Fan-out fabric (FabricTopology): hop 1 splits into per-leaf
        # switch pools — each tenant's persists/reads see only its
        # leaf's entries and capacity — while every leaf's drains merge
        # into the shared hop-2 spine (``hops[0]``), the fan-in point.
        # ``bp_high`` is the spine's Dirty-occupancy watermark: at/over
        # it, leaf drain-downs defer (victim drains are exempt — they
        # make room for an ack the CPU is already waiting on).  Without
        # a fabric everything lives on leaf 0 with the full n_pbe, so
        # every scoped path degenerates to the chain behaviour.
        fab = config.fabric
        self._n_leaves = fab.n_leaves if fab is not None else 1
        self._leaf_pbe = fab.leaf_pbe if fab is not None else (config.n_pbe,)
        self._bp_high = fab.bp_high if fab is not None else None
        # Epoched schedules: the declarative QoS policy / placement views
        # below (`self.policy`, `self._tenant_counts`, ...) are caches of
        # the *current epoch's* resolved values, derived by `set_epoch`
        # from the same `params.resolve_epoch` the engine lowering uses
        # (PCSConfig normalizes the legacy float knobs into a default
        # PBPolicy, so config.policy is always set; a schedule-free
        # config resolves identically at every epoch).  The driver
        # advances the epoch between slots via `set_epoch(epoch_at(t))`.
        self.set_epoch(0)
        # per-switch telemetry rows (engine twin: MachineState.hop_stats)
        self.hop_counts: List[Dict[str, int]] = [
            {"commits": 0, "coalesces": 0, "bypasses": 0, "read_hits": 0}
            for _ in self._hop_pbes]
        self._lru_clock = 0
        self._seq = 0
        self._version_clock = 0
        # Writes stalled at the PI buffer waiting for an Empty entry:
        # (addr, data, tenant, claim_below, lat_over) — `claim_below`
        # (non-None for quota-parked packets) gates the claim on the
        # tenant's own footprint shrinking below its park-time
        # occupancy; `lat_over` preserves the driver's SLO hint across
        # the re-park/replay cycle.
        self.pi_stalled: List[
            Tuple[int, object, int, Optional[int], Optional[bool]]] = []
        # Drains in flight: addr -> version sent (ack frees the entry).
        self.in_flight: Dict[int, int] = {}
        self.stats = {
            "persists": 0,
            "acks": 0,
            "drains": 0,       # hop-1 drain emissions (DRAIN_SENT events)
            "pm_writes": 0,    # write packets that reached the PM device
            "coalesces": 0,
            "read_hits": 0,
            "read_misses": 0,
            "stalls": 0,
            "slo_over": 0,     # persists over DrainPolicy.latency_target_ns
        }
        # Per-tenant accounting over the shared buffer: every event is
        # attributed to the tenant whose request triggered it (a policy
        # drain evicting another tenant's entry bills the *trigger*,
        # mirroring the timed engine's ctx.tenant attribution).
        self.tenant_stats: Dict[int, Dict[str, int]] = {}

    def _tstats(self, tenant: int) -> Dict[str, int]:
        if tenant not in self.tenant_stats:
            self.tenant_stats[tenant] = {k: 0 for k in self.stats}
        return self.tenant_stats[tenant]

    # -------------------------------------------------------------- epochs
    def set_epoch(self, epoch: int) -> None:
        """Re-derive every policy/placement cache for ``epoch``.

        The untimed twin of the engine's per-op operand selection
        (``engine.step.resolve_epoch_sc``): quota/share, the
        threshold/preset drain counts (global, per-tenant and per-hop),
        the serving-SLO target, and the tenant->leaf placement all come
        from ``params.resolve_epoch`` / ``params.epoch_value`` at the
        given epoch index.  Buffered entries are untouched — a placement
        flip migrates no entries (``_alloc_slot`` never moves an entry
        between leaves), so in-flight lines keep draining under their
        issue-time leaf exactly like the engine's slot-resident state.
        Idempotent; schedule-free configs resolve identically at every
        epoch.
        """
        self.epoch = int(epoch)
        cfg = self.config
        self.policy = resolve_epoch(cfg.policy, self.epoch)
        self._tenant_counts = (
            tenant_drain_counts(self.policy, cfg.n_pbe, cfg.n_tenants)
            if self.policy.drain.per_tenant else None)
        self._lat_target = self.policy.drain.latency_target_ns
        self._lat_tol = self.policy.drain.latency_tol
        self._thr_cnt = threshold_count(cfg.n_pbe,
                                        self.policy.drain.threshold)
        self._pre_cnt = preset_count(cfg.n_pbe, self.policy.drain.preset)
        fab = cfg.fabric
        self._placement = (epoch_value(fab.placement, self.epoch)
                           if fab is not None else None)
        self._hop_drain = (hop_drain_counts(self.policy, self._hop_pbes)
                           if self.n_hops else [])

    def epoch_at(self, t_ns: float) -> int:
        """Epoch index active at ``t_ns`` (boundary instants belong to
        the *new* epoch — ``params.epoch_index`` is the single home of
        that rule, shared with the engine's issue-time gate)."""
        return epoch_index(self.config.epoch_boundaries, t_ns)

    # ------------------------------------------------------------- helpers
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _touch(self, e: PBEntry) -> None:
        self._lru_clock += 1
        e.lru = self._lru_clock

    def _leaf_of(self, tenant: int) -> int:
        """Leaf switch serving ``tenant`` (0 without a fabric)."""
        if self._placement is None:
            return 0
        return (self._placement[tenant]
                if 0 <= tenant < len(self._placement) else 0)

    def _find(self, addr: int, leaf: int = 0) -> Optional[PBEntry]:
        """Newest live entry for addr on ``leaf`` (Dirty supersedes
        Drain).  Leaves are physically separate switches, so a lookup
        never sees another leaf's entries."""
        best: Optional[PBEntry] = None
        for e in self.entries:
            if (e.addr == addr and e.state != PBEState.EMPTY
                    and e.leaf == leaf):
                if best is None or e.version > best.version:
                    best = e
        return best

    def _count(self, state: PBEState) -> int:
        return sum(1 for e in self.entries if e.state == state)

    def _alloc_slot(self, leaf: int = 0) -> Optional[PBEntry]:
        """Return an Empty entry of ``leaf``, materializing the leaf's
        fixed capacity lazily (entries never migrate between leaves —
        the engine's slot windows are a static partition)."""
        for e in self.entries:
            if e.state == PBEState.EMPTY and e.leaf == leaf:
                return e
        if (sum(1 for e in self.entries if e.leaf == leaf)
                < self._leaf_pbe[leaf]):
            e = PBEntry(addr=-1, version=-1, data=None,
                        state=PBEState.EMPTY, lru=0, leaf=leaf)
            self.entries.append(e)
            return e
        return None

    def _lru_dirty(self, owner: Optional[int] = None,
                   leaf: Optional[int] = None) -> Optional[PBEntry]:
        dirty = [e for e in self.entries if e.state == PBEState.DIRTY
                 and (owner is None or e.tenant == owner)
                 and (leaf is None or e.leaf == leaf)]
        if not dirty:
            return None
        return min(dirty, key=lambda e: e.lru)

    def _occupancy(self, tenant: int) -> int:
        """Live (Dirty+Drain) entries owned by ``tenant`` — the quota /
        share accounting base (engine twin: ``policy.tenant_occupancy``)."""
        return sum(1 for e in self.entries
                   if e.state != PBEState.EMPTY and e.tenant == tenant)

    def _pick_victim(self, tenant: int,
                     leaf: int = 0) -> Optional[PBEntry]:
        """No-Empty victim under the AllocPolicy (engine twin:
        ``engine.policy.select_slot``'s dirty mask).

        ``victim="weighted"`` prefers the LRU Dirty entry of a tenant
        at/over its share; falls back to the LRU Dirty entry.  Both
        searches see only ``leaf``'s entries (the engine scopes the
        dirty mask with ``fabric.leaf_mask``); the share accounting
        stays global, like the engine's ``tenant_occupancy``.
        """
        pol = self.policy.alloc
        if pol.victim == "weighted":
            occ: Dict[int, int] = {}
            for e in self.entries:
                if e.state != PBEState.EMPTY:
                    occ[e.tenant] = occ.get(e.tenant, 0) + 1
            hot = [e for e in self.entries if e.state == PBEState.DIRTY
                   and e.leaf == leaf
                   and occ.get(e.tenant, 0) >= pol.share_of(
                       e.tenant, self.config.n_pbe, self.config.n_tenants)]
            if hot:
                return min(hot, key=lambda e: e.lru)
        return self._lru_dirty(leaf=leaf)

    # --------------------------------------------------------------- drain
    def _start_drain(self, e: PBEntry, events: List[Event],
                     tenant: int = 0, *, forward: bool = True) -> tuple:
        """Dirty -> Drain; emit the write packet downstream (Section V-B).

        ``tenant`` is the tenant whose request *triggered* the drain
        (victim eviction / policy drain-down) — the one billed for it.
        With a single switch (or ``forward=False``, the recovery
        drain-all) the payload goes straight to PM; in a chain the
        caller forwards the returned packet into hop 2 via
        :meth:`_forward_batch` — batched with the other drains of the
        same trigger, mirroring the engine's cascade batches.  Either
        way the entry is freed by the downstream ack the driver delivers
        through :meth:`pm_ack`.
        """
        assert e.state == PBEState.DIRTY
        e.state = PBEState.DRAIN
        self.in_flight[(e.addr, e.version)] = True
        self.stats["drains"] += 1
        self._tstats(tenant)["drains"] += 1
        events.append(Event(EventKind.DRAIN_SENT, e.addr, e.version,
                            self._next_seq()))
        if self.config.n_switches <= 1 or not forward:
            # The PM device receives the write; its ack is delivered
            # later by the caller via pm_ack() (possibly delayed).
            self.pm.write(e.addr, e.version, e.data)
            self.stats["pm_writes"] += 1
            self._tstats(tenant)["pm_writes"] += 1
        return (e.addr, e.version, e.data, e.tenant)

    def _forward_batch(self, packets: List[tuple], s: int,
                       tenant: int) -> None:
        """Commit a drain batch into switch ``s``'s PB, then run its drain.

        The untimed twin of ``engine.chain._place``: packets (all with
        distinct addresses) coalesce into a live Dirty entry, else take
        an Empty slot, else *bypass* the full hop and continue toward
        PM; afterwards the hop's own drain policy runs once over the
        settled table (PB forwards everything, PB_RF drains LRU Dirty
        entries down to its per-hop preset).  Chain-internal acks are
        synchronous in the untimed model, so a forwarded entry frees
        immediately — matching the engine at slot boundaries, where
        every cascade ack has long landed.  ``tenant`` is the trigger
        billed for PM writes (engine twin: ``ctx.tenant``).
        """
        if not packets:
            return
        if s > self.config.n_switches:
            ts = self._tstats(tenant)
            for (addr, ver, data, _owner) in packets:
                self.pm.write(addr, ver, data)
                self.stats["pm_writes"] += 1
                ts["pm_writes"] += 1
            return
        hop = self.hops[s - 2]
        cap = self._hop_pbes[s - 1]
        hc = self.hop_counts[s - 1]
        bypass: List[tuple] = []
        for (addr, ver, data, owner) in packets:
            e = next((x for x in hop
                      if x.addr == addr and x.state == PBEState.DIRTY),
                     None)
            if e is not None:
                # fan-in max-version coalesce: within one leaf (and in a
                # linear chain) same-line versions travel in order, so
                # the arriving packet always wins; across leaves an
                # older version can arrive *after* a newer one already
                # sitting in the spine, and must not roll it back — the
                # resident copy keeps its version/data/owner (engine
                # twin: ``chain._place``'s max-version rule)
                if ver >= e.version:
                    e.version, e.data, e.tenant = ver, data, owner
                self._touch(e)
                hc["commits"] += 1
                hc["coalesces"] += 1
                continue
            slot = next((x for x in hop if x.state == PBEState.EMPTY),
                        None)
            if slot is None and len(hop) < cap:
                slot = PBEntry(addr=-1, version=-1, data=None,
                               state=PBEState.EMPTY, lru=0)
                hop.append(slot)
            if slot is None:
                hc["bypasses"] += 1
                bypass.append((addr, ver, data, owner))
                continue
            slot.addr, slot.version, slot.data = addr, ver, data
            slot.state, slot.tenant = PBEState.DIRTY, owner
            self._touch(slot)
            hc["commits"] += 1
        # the hop's own drain-down, once per batch (engine lockstep)
        dirty = [x for x in hop if x.state == PBEState.DIRTY]
        if self.config.scheme == Scheme.PB:
            k = len(dirty)          # drain-immediate: store and forward
        else:
            thr, pre = self._hop_drain[s - 1]
            # deep hops run the pure threshold/preset rule — no
            # keep-one-free heuristic (it protects the hop-1 PI front)
            k = rf_drain_count(len(dirty), 0, thr, pre,
                               low_water=0, empty_slack=-1)
        out: List[tuple] = []
        for victim in sorted(dirty, key=lambda x: x.lru)[:k]:
            out.append((victim.addr, victim.version, victim.data,
                        victim.tenant))
            victim.state = PBEState.EMPTY     # synchronous downstream ack
        self._forward_batch(bypass + out, s + 1, tenant)

    def _rf_drain_down(self, events: List[Event], tenant: int = 0) -> None:
        """PB_RF drain policy, shared with the timed engine.

        The decision (threshold/preset drain-down plus the keep-one-free
        low-water heuristic) lives in ``params.rf_drain_count`` (the
        shared policy scalar, re-exported by ``engine.policy``); this
        method only supplies the counts and drains the LRU Dirty victims
        it asks for.
        """
        if self.config.scheme != Scheme.PB_RF:
            return
        # backpressure-aware scheduling (FabricTopology.bp_high): while
        # the downstream spine FIFO sits at/over its Dirty watermark,
        # the whole leaf drain-down — threshold and low-water legs —
        # defers; the Dirty entries stay put and the next persist
        # re-evaluates (engine twin: the ``defer`` override in
        # ``engine.policy.drain_threshold_preset``)
        if (self._bp_high is not None and self.hops
                and sum(1 for e in self.hops[0]
                        if e.state == PBEState.DIRTY) >= self._bp_high):
            return
        pol = self.policy.drain
        leaf = self._leaf_of(tenant)
        # the drain-down runs on the trigger tenant's *leaf* switch: it
        # sees that leaf's Dirty entries and Empty pool only (engine
        # twin: ``leaf_act`` as the policy's slot mask)
        empty = self._leaf_pbe[leaf] - sum(
            1 for e in self.entries
            if e.state != PBEState.EMPTY and e.leaf == leaf)
        if pol.per_tenant:
            # tenant-scoped drain-down: the trigger's Dirty count against
            # *its* counts (quota / fair-share anchored), draining only
            # its own LRU Dirty entries — a noisy tenant can no longer
            # evict a quiet tenant's Dirty entries.  The keep-one-free
            # heuristic still watches the leaf's Empty pool.
            scope = tenant
            dirty = sum(1 for e in self.entries
                        if e.state == PBEState.DIRTY and e.tenant == tenant)
            thr, pre = self._tenant_counts[tenant]
        else:
            scope = None
            dirty = sum(1 for e in self.entries
                        if e.state == PBEState.DIRTY and e.leaf == leaf)
            thr, pre = self._thr_cnt, self._pre_cnt
        # serving-SLO tightening (engine twin: the ``tight`` override in
        # ``engine.policy.drain_threshold_preset``): while the trigger
        # tenant's observed over-target fraction exceeds its tolerance,
        # drain every in-scope Dirty entry ASAP (threshold 1, preset 0)
        if (self._lat_target is not None
                and self._tstats(tenant)["slo_over"]
                > self._lat_tol * self._slo_cnt.get(tenant, 0)):
            thr, pre = 1, 0
        k = rf_drain_count(dirty, empty, thr, pre,
                           pol.low_water_drains, pol.empty_slack)
        packets = []
        for _ in range(k):
            victim = self._lru_dirty(owner=scope, leaf=leaf)
            if victim is None:
                break
            packets.append(self._start_drain(victim, events, tenant))
        # chain: the drain-down set travels to hop 2 as ONE batch (the
        # engine's policy-drain leg); no-op with a single switch
        if self.config.n_switches >= 2:
            self._forward_batch(packets, 2, tenant)

    def _slo_note(self, tenant: int, lat_over: Optional[bool]) -> None:
        """Record one *completed* persist's SLO outcome.

        ``lat_over`` is the driver's timing hint (ack latency over
        ``DrainPolicy.latency_target_ns``); the untimed oracle cannot
        compute latencies itself.  The counters feed the tight override
        in :meth:`_rf_drain_down` and the engine differential
        (``S_PERSIST_CNT`` / ``S_SLO_OVER`` twins).
        """
        self._slo_cnt[tenant] = self._slo_cnt.get(tenant, 0) + 1
        if lat_over:
            self.stats["slo_over"] += 1
            self._tstats(tenant)["slo_over"] += 1

    def _stall(self, addr: int, data: object, tenant: int, version: int,
               events: List[Event], retry: bool,
               claim_below: Optional[int],
               lat_over: Optional[bool] = None) -> List[Event]:
        """Park the write at the PI buffer until an entry frees (V-D1).

        A *retry* (a previously stalled packet replayed by
        :meth:`pm_ack`) is re-parked without re-billing: the engine
        counts one victim/stall event per original packet no matter how
        long it waits, so only the packet's first stall emits STALLED
        and bumps the stall counters.  ``claim_below`` (non-None for
        quota-parked packets) is the tenant's occupancy at park time:
        the packet may only claim a slot once its tenant's footprint
        shrank below it — i.e. once one of its *own* entries freed — so
        the recycle restores exactly the park-time occupancy, like the
        engine's over-quota victim path (see :meth:`persist`).
        """
        ts = self._tstats(tenant)
        self.pi_stalled.append((addr, data, tenant, claim_below, lat_over))
        self.stats["persists"] -= 1
        ts["persists"] -= 1
        self._version_clock -= 1
        if not retry:
            self.stats["stalls"] += 1
            ts["stalls"] += 1
            events.append(Event(EventKind.STALLED, addr, version,
                                self._next_seq()))
        return events

    # ------------------------------------------------------------- persist
    def persist(self, addr: int, data: object,
                tenant: int = 0, *, _retry: bool = False,
                _claim_below: Optional[int] = None,
                lat_over: Optional[bool] = None) -> List[Event]:
        """A persist (flush+fence) packet reaches the switch.

        ``tenant`` tags which host issued it (multi-tenant sharing of
        the switch); all events it triggers are billed to that tenant.
        ``lat_over`` is the driver's SLO hint: whether this persist's
        *timed* ack latency exceeded ``DrainPolicy.latency_target_ns``
        (ignored — and irrelevant — when no target is set); it feeds the
        tight drain-down override via :meth:`_slo_note` and is counted
        once, at completion.
        ``_retry`` marks the replay of a stalled packet (internal, from
        :meth:`pm_ack`): it re-attempts allocation but neither starts
        another victim drain nor re-counts the stall.  ``_claim_below``
        marks the replay of a quota-parked packet: it *recycles* the
        slot one of its own entries (typically its victim drain) freed,
        claiming only once its tenant's occupancy drops below the
        park-time value and bypassing the quota gate for that claim —
        occupancy is restored to the park-time level, exactly the timed
        engine's over-quota victim path (which writes into its victim's
        slot at the drain-ack time).  Without the exemption a tenant
        pushed *over* quota by a cross-tenant coalesce takeover could
        park a packet forever; without the own-entry gate the claim
        could transiently grow the footprint past the quota.
        """
        events: List[Event] = []
        ts = self._tstats(tenant)
        self.stats["persists"] += 1
        ts["persists"] += 1
        self._version_clock += 1
        version = self._version_clock

        if self.config.scheme == Scheme.NOPB:
            # Volatile switch: the persist round-trips to PM.
            self.pm.write(addr, version, data)
            self._slo_note(tenant, lat_over)
            self.stats["acks"] += 1
            self.stats["pm_writes"] += 1
            ts["acks"] += 1
            ts["pm_writes"] += 1
            events.append(Event(EventKind.PERSIST_ACK, addr, version,
                                self._next_seq()))
            return events

        leaf = self._leaf_of(tenant)
        existing = self._find(addr, leaf)
        if existing is not None and existing.state == PBEState.DIRTY:
            if self.config.scheme == Scheme.PB_RF:
                # Write coalescing: newer version absorbs the older one.
                existing.version = version
                existing.data = data
                existing.tenant = tenant
                self._touch(existing)
                self._slo_note(tenant, lat_over)
                self.stats["coalesces"] += 1
                self.stats["acks"] += 1
                ts["coalesces"] += 1
                ts["acks"] += 1
                self.hop_counts[0]["commits"] += 1
                self.hop_counts[0]["coalesces"] += 1
                events.append(Event(EventKind.COALESCED, addr, version,
                                    self._next_seq()))
                events.append(Event(EventKind.PERSIST_ACK, addr, version,
                                    self._next_seq()))
                # The drain-down policy is evaluated on every persist,
                # coalesces included (the engine's drain_threshold_preset
                # runs unconditionally).  Under the global policy a
                # coalesce never changes the Dirty count so this is
                # unreachable work, but a cross-tenant coalesce takeover
                # *does* move the owning tenant's Dirty count across its
                # scoped threshold.
                self._rf_drain_down(events, tenant)
                return events
            # PB scheme never observes Dirty (drain-immediately), but the
            # state machine stays safe if it does: fall through to stall.

        # Per-tenant PBE quota (AllocPolicy): a tenant at/over its cap
        # may not grow its footprint with an Empty slot — it recycles it
        # instead: drain its own LRU Dirty entry (none if all already in
        # flight) and wait at the PI buffer for one of its own entries
        # to free; the claim then restores the park-time occupancy (see
        # the docstring).  Coalescing above is exempt (reuses an entry).
        occ = self._occupancy(tenant)
        if _claim_below is not None:
            if occ >= _claim_below:
                # no own entry freed yet: keep waiting (silent re-park)
                return self._stall(addr, data, tenant, version, events,
                                   _retry, claim_below=_claim_below,
                                   lat_over=lat_over)
        elif occ >= self.policy.alloc.quota_of(tenant):
            if not _retry:
                victim = self._lru_dirty(owner=tenant, leaf=leaf)
                if victim is not None:
                    pkt = self._start_drain(victim, events, tenant)
                    # chain: the victim leg travels ahead of the entry
                    # write (engine lockstep: a one-packet batch)
                    if self.config.n_switches >= 2:
                        self._forward_batch([pkt], 2, tenant)
            return self._stall(addr, data, tenant, version, events,
                               _retry, claim_below=occ, lat_over=lat_over)

        # An in-flight (Drain) older version does NOT block the new persist:
        # the new version gets its own entry; the switch->PM path is FIFO,
        # so same-address drains reach PM in version order (Section IV-A
        # write order without blocking the ack).
        slot = self._alloc_slot(leaf)
        if slot is None:
            if not _retry:
                victim = self._pick_victim(tenant, leaf)
                if victim is not None:
                    pkt = self._start_drain(victim, events, tenant)
                    if self.config.n_switches >= 2:
                        self._forward_batch([pkt], 2, tenant)
            # Whether we drained a victim or everything is already Drain,
            # the write must wait for an Empty entry (Section V-D1).
            return self._stall(addr, data, tenant, version, events,
                               _retry, claim_below=_claim_below,
                               lat_over=lat_over)

        slot.addr = addr
        slot.version = version
        slot.data = data
        slot.state = PBEState.DIRTY
        slot.tenant = tenant
        self._touch(slot)
        self._slo_note(tenant, lat_over)
        self.stats["acks"] += 1
        ts["acks"] += 1
        self.hop_counts[0]["commits"] += 1
        events.append(Event(EventKind.PERSIST_ACK, addr, version,
                            self._next_seq()))

        if self.config.scheme == Scheme.PB:
            # Drain as soon as acked, to keep Empty entries available.
            pkt = self._start_drain(slot, events, tenant)
            if self.config.n_switches >= 2:
                self._forward_batch([pkt], 2, tenant)
        else:
            self._rf_drain_down(events, tenant)
        return events

    # -------------------------------------------------------------- pm ack
    def pm_ack(self, addr: int, version: int) -> List[Event]:
        """A PM write-ack packet reaches the switch (PI-front priority)."""
        events: List[Event] = []
        if (addr, version) not in self.in_flight:
            return events  # stale/unknown ack: ignore
        del self.in_flight[(addr, version)]
        for e in self.entries:
            if (e.addr == addr and e.state == PBEState.DRAIN
                    and e.version == version):
                e.state = PBEState.EMPTY
                events.append(Event(EventKind.DRAIN_ACKED, addr, version,
                                    self._next_seq()))
                break
        # Retry stalled writes now that an entry may be Empty.  Acks were
        # prioritized to the PI front precisely to enable this (V-D2).
        # Replays are marked _retry: a packet still blocked (no Empty /
        # still over quota) re-parks silently — one stall event and at
        # most one victim drain per original packet, like the engine.
        retries, self.pi_stalled = self.pi_stalled, []
        for (a, d, tn, cb, lo) in retries:
            events.extend(self.persist(a, d, tn, _retry=True,
                                       _claim_below=cb, lat_over=lo))
        return events

    # ---------------------------------------------------------------- read
    def read(self, addr: int,
             tenant: int = 0) -> Tuple[Optional[object], Event]:
        """A read request reaches the switch; returns (data, event)."""
        ts = self._tstats(tenant)
        e = self._find(addr, self._leaf_of(tenant))
        if e is not None and e.state in (PBEState.DIRTY, PBEState.DRAIN):
            # PBCS routes to PI; PBC serves from the buffer (V-D3).  Under
            # PB the entry is in Drain: serving from PB is still correct
            # (same bytes as the in-flight drain) and preserves write-read
            # order because the drain was emitted before this response.
            # A forwarded read refreshes the entry's LRU stamp, matching
            # the timed engine's victim-selection discipline.
            self._touch(e)
            self.stats["read_hits"] += 1
            ts["read_hits"] += 1
            self.hop_counts[0]["read_hits"] += 1
            return e.data, Event(EventKind.READ_FROM_PB, addr, e.version,
                                 self._next_seq())
        # chain read forwarding: the miss travels toward PM past every
        # deeper switch's PBCS — the shallowest hop holding a live entry
        # serves it (shallower always holds the newer version); NOPB has
        # no persistent hops (n_hops == 0)
        for s in range(2, self.n_hops + 1):
            d_e = next((x for x in self.hops[s - 2]
                        if x.addr == addr and x.state == PBEState.DIRTY),
                       None)
            if d_e is not None:
                self._touch(d_e)
                self.stats["read_hits"] += 1
                ts["read_hits"] += 1
                self.hop_counts[s - 1]["read_hits"] += 1
                return d_e.data, Event(EventKind.READ_FROM_PB, addr,
                                       d_e.version, self._next_seq())
        self.stats["read_misses"] += 1
        ts["read_misses"] += 1
        rec = self.pm.read(addr)
        data = rec[1] if rec is not None else None
        ver = rec[0] if rec is not None else -1
        return data, Event(EventKind.READ_FROM_PM, addr, ver,
                           self._next_seq())

    # ----------------------------------------------------- crash / recover
    def crash(self) -> None:
        """Power loss: routing state (PI/PO, in-flight acks) is lost; the
        PB tables survive (non-volatile cells / battery), Section V-D4."""
        self.pi_stalled.clear()
        self.in_flight.clear()
        # Entries survive with their states; nothing else to do.

    def recover(self) -> List[Event]:
        """Reboot: treat every non-Empty entry — at every hop — as Dirty
        and drain the union straight to PM (the device rejects stale
        versions, so duplicate addresses across hops resolve to the
        newest surviving copy regardless of drain order)."""
        events: List[Event] = []
        for e in self.entries:
            if e.state in (PBEState.DIRTY, PBEState.DRAIN):
                e.state = PBEState.DIRTY
                # recovery drains belong to the entry's owning tenant;
                # forward=False: drain-all bypasses the (rebooting) chain
                self._start_drain(e, events, e.tenant, forward=False)
        for hop in self.hops:
            for e in hop:
                if e.state in (PBEState.DIRTY, PBEState.DRAIN):
                    # deep entries sit outside the hop-1 ack protocol:
                    # their recovery drain completes synchronously
                    self.pm.write(e.addr, e.version, e.data)
                    self.stats["drains"] += 1
                    self.stats["pm_writes"] += 1
                    self._tstats(e.tenant)["drains"] += 1
                    self._tstats(e.tenant)["pm_writes"] += 1
                    events.append(Event(EventKind.DRAIN_SENT, e.addr,
                                        e.version, self._next_seq()))
                    events.append(Event(EventKind.DRAIN_ACKED, e.addr,
                                        e.version, self._next_seq()))
                    e.state = PBEState.EMPTY
        # Recovery drains are immediately acked in this untimed model.
        for e in self.entries:
            if e.state == PBEState.DRAIN:
                events.extend(self.pm_ack(e.addr, e.version))
        return events

    # ----------------------------------------------------- durable snapshot
    def snapshot_durable(self) -> Dict[int, Tuple[int, object]]:
        """What a crash-now + recovery would preserve, without mutating.

        The durable domain is PM plus the PB's persistent cells: for
        every address, the newest version between the PM store and any
        live (Dirty/Drain) entry — exactly what ``crash(); recover()``
        leaves in PM, since recovery re-drains every live entry and the
        device rejects stale writes.  ``tests/test_semantics.py`` pins
        this equivalence; the crash-differential harness uses it to
        read the oracle's durable state at arbitrary crash points.
        """
        durable: Dict[int, Tuple[int, object]] = dict(self.pm.store)
        for hop in [self.entries, *self.hops]:
            for e in hop:
                if e.state == PBEState.EMPTY:
                    continue
                cur = durable.get(e.addr)
                if cur is None or e.version > cur[0]:
                    durable[e.addr] = (e.version, e.data)
        return durable

    def hop_surviving(self) -> List[int]:
        """Live (non-Empty) PBEs per switch — what a crash right now
        would leave for the per-hop recovery drain-all (engine twin:
        ``SimResult.hop_recovery``)."""
        return [sum(1 for e in hop if e.state != PBEState.EMPTY)
                for hop in [self.entries, *self.hops]][:self.n_hops]

    def leaf_surviving(self) -> List[int]:
        """Live (non-Empty) hop-1 PBEs per leaf switch — the fabric's
        per-leaf crash attribution (engine twin:
        ``SimResult.leaf_recovery``).  Sums to ``hop_surviving()[0]``;
        spine survivors are ``hop_surviving()[1]``."""
        out = [0] * self._n_leaves
        for e in self.entries:
            if e.state != PBEState.EMPTY:
                out[e.leaf] += 1
        return out

    # ------------------------------------------------------------ invariant
    def check_invariants(self) -> None:
        """The paper's three correctness criteria, checkable at any time.

        Under a multi-leaf fabric the *global-ordering* forms are
        genuinely weaker — two leaves are independent switches, so a
        newer version can reach PM through one leaf while an older copy
        of the same line is still live on another — and the affected
        checks scope to a leaf (or are skipped where no leaf-local form
        exists).  End-to-end safety then rests on the PM device's
        stale-write rejection, which the property tests pin.
        """
        multi_leaf = self._n_leaves >= 2
        # (c) crash consistency, internal form: a Dirty entry is by
        #     definition the latest-and-only copy, so PM must never hold a
        #     version newer than a live Dirty entry.  (An older *Drain*
        #     entry may coexist with a newer PM version when acks return
        #     out of order; recovery re-drains it and PM rejects the stale
        #     write, so nothing is lost.)  The external form — "no acked
        #     version is ever lost" — is asserted by the property tests,
        #     which track acks outside the buffer.  With >= 2 leaves
        #     another leaf's drain may legitimately land a newer version
        #     in PM, so the check has no leaf-local form and is skipped.
        if not multi_leaf:
            for e in self.entries:
                if e.state != PBEState.DIRTY:
                    continue
                rec = self.pm.read(e.addr)
                if rec is not None and rec[0] > e.version:
                    raise AssertionError(
                        f"PM holds newer version than live Dirty PB entry "
                        f"for addr={e.addr}: pm={rec[0]} pb={e.version}")
        # (b) write order: at most one Dirty entry per (leaf, address),
        #     and every Drain entry for an address is strictly older than
        #     its *same-leaf* Dirty entry (versions drain toward PM in
        #     order within each leaf's FIFO; across leaves no order is
        #     promised).
        dirty = [(e.leaf, e.addr) for e in self.entries
                 if e.state == PBEState.DIRTY]
        if len(dirty) != len(set(dirty)):
            raise AssertionError(
                "duplicate Dirty PB entries for one (leaf, address)")
        newest_dirty = {(e.leaf, e.addr): e.version for e in self.entries
                        if e.state == PBEState.DIRTY}
        for e in self.entries:
            if (e.state == PBEState.DRAIN
                    and (e.leaf, e.addr) in newest_dirty
                    and e.version >= newest_dirty[(e.leaf, e.addr)]):
                raise AssertionError(
                    f"Drain entry not older than Dirty for addr={e.addr} "
                    f"on leaf {e.leaf}")
        # Switch-chain forms of (b) and (c): per hop at most one Dirty
        # entry per address; versions strictly decrease with depth (an
        # entry only moves down the chain, and coalescing keeps the
        # newest at the shallowest hop holding the line); PM never holds
        # a version newer than any live Dirty entry at any hop.  The
        # per-hop uniqueness holds under fan-in too (the spine's
        # max-version coalesce keeps one Dirty per address), but the
        # cross-layer orderings do not — a slow leaf's old Dirty line
        # may coexist with a newer spine/PM copy — so those scope to
        # single-leaf topologies.
        newest_by_addr: Dict[int, int] = {
            a: v for (_lf, a), v in newest_dirty.items()}
        for s, hop in enumerate(self.hops, start=2):
            hop_dirty = [e.addr for e in hop if e.state == PBEState.DIRTY]
            if len(hop_dirty) != len(set(hop_dirty)):
                raise AssertionError(
                    f"duplicate Dirty entries for one address at hop {s}")
            if multi_leaf:
                continue
            for e in hop:
                if e.state != PBEState.DIRTY:
                    continue
                if (e.addr in newest_by_addr
                        and e.version >= newest_by_addr[e.addr]):
                    raise AssertionError(
                        f"hop {s} holds a version not older than a "
                        f"shallower hop's for addr={e.addr}")
                newest_by_addr[e.addr] = e.version
                rec = self.pm.read(e.addr)
                if rec is not None and rec[0] > e.version:
                    raise AssertionError(
                        f"PM holds newer version than hop-{s} Dirty entry "
                        f"for addr={e.addr}: pm={rec[0]} pb={e.version}")
