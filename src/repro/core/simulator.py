"""Compatibility shim over ``repro.core.engine``.

The monolithic ``_simulate`` scan that used to live here was decomposed
into the composable ``core.engine`` package (DESIGN.md §3): machine
state + step driver, per-op handlers, a pluggable PB policy layer with
traced-scheme dispatch, the PM/PBC resource model, and the batched
``simulate_grid`` front-end.  ``simulate`` / ``simulate_sweep`` keep
their original signatures and return identical ``SimResult`` objects;
new code should import from ``repro.core.engine`` directly and prefer
``simulate_grid`` for anything that sweeps.
"""
from repro.core.engine import (SimResult, simulate,  # noqa: F401
                               simulate_grid, simulate_sweep)

__all__ = ["SimResult", "simulate", "simulate_grid", "simulate_sweep"]
