"""Timed PCS simulator: a jit/vmap-able replacement for the paper's gem5 run.

The gem5 SE-mode simulation of the paper is replaced by a *trace-driven
queueing simulator* expressed as one ``jax.lax.scan`` over the merged
memory-request stream of all cores.  The scan carry holds the entire
machine state:

    * per-core clocks + trace cursors (fence semantics: a core blocks on
      its persists and PM reads),
    * the PB tables (TAT tags, ST states, LRU stamps) plus the in-flight
      drain-completion times — the Data Table carries no payload here
      because timing does not depend on the bytes,
    * resource next-free times: the PM controller channel and the PBC
      (head-of-line blocking of reads behind stalled writes — the effect
      behind the paper's Fig. 6b read-latency increase),
    * the statistics accumulators behind Figs. 1 and 5-8.

PM write acks are modeled *lazily*: when a drain is scheduled, its ack
arrival time at the switch is computed immediately (PM queueing included)
and stored per entry; any later event observes Drain->Empty transitions
whose ack time has passed.  This reproduces exactly the effect of the
paper's PI-buffer ack-priority rule (acks never wait behind stalled
writes) with one scan step per trace op.

Scheme and buffer capacity bound are static (compile-time); every latency
parameter and the live entry count are traced scalars, so Figure 8's PBE
sweep and Figure 1's switch-depth sweep are single ``vmap`` calls.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import LatencyProfile, Op, PBEState, PCSConfig, Scheme
from repro.core.traces import Trace

INF = 1e30

# statistics vector layout
S_PERSIST_SUM = 0
S_PERSIST_CNT = 1
S_READ_SUM = 2
S_READ_CNT = 3
S_READ_HITS = 4
S_COALESCES = 5
S_PM_WRITES = 6
S_STALL_TIME = 7
S_PI_DETOURS = 8
S_DRAM_READS = 9
S_VICTIM_CNT = 10    # persists that took the no-Empty victim path
S_PBCQ_SUM = 11      # total PBC queueing wait (arrival -> service start)
N_STATS = 12

EMPTY = int(PBEState.EMPTY)
DIRTY = int(PBEState.DIRTY)
DRAIN = int(PBEState.DRAIN)


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Aggregate metrics of one simulated run."""

    runtime_ns: float
    persist_lat_ns: float       # mean persist latency (fence round trip)
    read_lat_ns: float          # mean PM-read latency (from LLC)
    persists: int
    pm_reads: int
    read_hits: int              # reads served from the PB
    coalesces: int              # persists absorbed into a Dirty entry
    pm_writes: int              # write packets that reached the PM device
    stall_ns: float             # PBC time spent waiting for Empty entries
    pi_detours: int             # reads routed through the PI buffer

    @property
    def read_hit_rate(self) -> float:
        return self.read_hits / max(self.pm_reads, 1)

    @property
    def coalesce_rate(self) -> float:
        return self.coalesces / max(self.persists, 1)


def _scalars_from_config(cfg: PCSConfig) -> Dict[str, float]:
    lat = cfg.latency
    return dict(
        n_pbe=float(cfg.n_pbe),
        threshold_count=float(cfg.threshold_count),
        preset_count=float(cfg.preset_count),
        tag_ns=lat.pb_tag_ns_for(cfg.n_pbe),
        data_ns=lat.pb_data_ns_for(cfg.n_pbe),
        pbc_proc_ns=lat.pbc_proc_ns,
        pbc_occ_ns=lat.pbc_occ_ns,
        pbc_read_ns=lat.pbc_read_ns,
        pbc_read_occ=lat.pbc_read_occ_ns,
        nvm_read=lat.nvm_read_ns,
        nvm_write=lat.nvm_write_ns,
        nvm_r_occ=lat.nvm_read_occ_ns,
        nvm_w_occ=lat.nvm_write_occ_ns,
        dram_ns=lat.dram_ns,
        fwd_margin=lat.fwd_margin_ns,
        switch_pipe=lat.switch_pipe_ns,
        ow_cpu_pm=lat.oneway_cpu_pm(cfg.n_switches),
        ow_cpu_sw1=lat.oneway_cpu_sw1() if cfg.n_switches > 0 else lat.cpu_link_ns,
        ow_sw1_pm=lat.oneway_sw1_pm(cfg.n_switches) if cfg.n_switches > 0 else 0.0,
    )


@functools.partial(jax.jit,
                   static_argnames=("scheme", "max_pbe", "n_steps", "pm_banks"))
def _simulate(ops, addrs, gaps, lengths, sc, *, scheme: int, max_pbe: int,
              n_steps: int, pm_banks: int = 4):
    """Run the scan.  ``sc`` is the dict of traced latency scalars."""
    C = ops.shape[0]
    B = pm_banks
    slot_ids = jnp.arange(max_pbe)
    slot_active = slot_ids < sc["n_pbe"].astype(jnp.int32)

    def lazy_free(state, dd, now):
        freed = (state == DRAIN) & (dd <= now)
        return jnp.where(freed, EMPTY, state)

    def step(carry, _):
        (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, blocked,
         bcount, stats) = carry
        active = ptr < lengths
        # blocked cores wait at a barrier and cannot be selected
        tsel = jnp.where(active & ~blocked, clock, INF)
        c = jnp.argmin(tsel)
        # padded steps after exhaustion (or a barrier mismatch) are no-ops
        valid = jnp.any(active) & (tsel[c] < INF * 0.5)
        i = jnp.minimum(ptr[c], lengths[c] - 1)
        op = jnp.where(valid, ops[c, i], int(Op.COMPUTE))
        addr = addrs[c, i]
        gap = jnp.where(valid, gaps[c, i].astype(jnp.float64), 0.0)
        t = jnp.where(valid, tsel[c], clock[c]) + gap

        # ---------------- volatile branches -------------------------------
        def br_compute(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            return (clock.at[c].set(t), ptr, tag, state, lru, dd,
                    pm_busy, pbc_busy, stats)

        def br_dram_read(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            stats = stats.at[S_DRAM_READS].add(1.0)
            return (clock.at[c].set(t + sc["dram_ns"]), ptr, tag, state,
                    lru, dd, pm_busy, pbc_busy, stats)

        def br_dram_write(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            return (clock.at[c].set(t), ptr, tag, state, lru, dd,
                    pm_busy, pbc_busy, stats)

        # ---------------- PM read -----------------------------------------
        def br_pm_read(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            ow = sc["ow_cpu_pm"]
            bank = addr % B
            # direct path (NoPB, or no PB entry for this line)
            pm_start_dir = jnp.maximum(pm_busy[bank], t + ow)
            resp_dir = pm_start_dir + sc["nvm_read"] + ow

            if scheme == int(Scheme.NOPB):
                stats = stats.at[S_READ_SUM].add(resp_dir - t)
                stats = stats.at[S_READ_CNT].add(1.0)
                return (clock.at[c].set(resp_dir), ptr, tag, state, lru, dd,
                        pm_busy.at[bank].set(pm_start_dir + sc["nvm_r_occ"]),
                        pbc_busy, stats)

            state0 = lazy_free(state, dd, t)
            match = slot_active & (tag == addr) & (state0 != EMPTY)
            has = jnp.any(match)
            # newest version first: a Dirty entry supersedes a Drain one
            idx = jnp.argmax(match & (state0 == DIRTY)) * jnp.any(
                match & (state0 == DIRTY)) + jnp.argmax(match) * (
                ~jnp.any(match & (state0 == DIRTY)))
            # PI-buffer path: wait for the PBC (head-of-line blocking)
            arr = t + sc["ow_cpu_sw1"]
            pbc_start = (jnp.maximum(pbc_busy, arr)
                         + sc["pbc_read_ns"] + sc["tag_ns"])
            st_i = state0[idx]
            dd_i = dd[idx]
            served = (st_i == DIRTY) | (
                (st_i == DRAIN) & (dd_i > pbc_start + sc["fwd_margin"]))
            resp_pb = pbc_start + sc["data_ns"] + sc["ow_cpu_sw1"]
            # forwarded to PM through the PO buffer after the detour; the
            # packet re-enters the routing pipeline (one extra pipe pass)
            pm_start_fwd = jnp.maximum(
                pm_busy[bank],
                pbc_start + sc["switch_pipe"] + sc["ow_sw1_pm"])
            resp_fwd = pm_start_fwd + sc["nvm_read"] + ow

            resp = jnp.where(has, jnp.where(served, resp_pb, resp_fwd),
                             resp_dir)
            pm_busy2 = pm_busy.at[bank].set(jnp.where(
                has,
                jnp.where(served, pm_busy[bank],
                          pm_start_fwd + sc["nvm_r_occ"]),
                pm_start_dir + sc["nvm_r_occ"]))
            pbc_busy2 = jnp.where(
                has, jnp.maximum(pbc_busy, arr) + sc["pbc_read_occ"],
                pbc_busy)
            lru2 = lru.at[idx].set(jnp.where(has & served, t, lru[idx]))
            stats = stats.at[S_READ_SUM].add(resp - t)
            stats = stats.at[S_READ_CNT].add(1.0)
            stats = stats.at[S_READ_HITS].add((has & served).astype(jnp.float64))
            stats = stats.at[S_PI_DETOURS].add(has.astype(jnp.float64))
            return (clock.at[c].set(resp), ptr, tag, state0, lru2, dd,
                    pm_busy2, pbc_busy2, stats)

        # ---------------- persist -----------------------------------------
        def br_persist(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            ow = sc["ow_cpu_pm"]
            bank = addr % B
            if scheme == int(Scheme.NOPB):
                pm_start = jnp.maximum(pm_busy[bank], t + ow)
                ack = pm_start + sc["nvm_write"] + ow
                stats = stats.at[S_PERSIST_SUM].add(ack - t)
                stats = stats.at[S_PERSIST_CNT].add(1.0)
                stats = stats.at[S_PM_WRITES].add(1.0)
                return (clock.at[c].set(ack), ptr, tag, state, lru, dd,
                        pm_busy.at[bank].set(pm_start + sc["nvm_w_occ"]),
                        pbc_busy, stats)

            arr = t + sc["ow_cpu_sw1"]
            pbc_start = (jnp.maximum(pbc_busy, arr)
                         + sc["pbc_proc_ns"] + sc["tag_ns"])
            state1 = lazy_free(state, dd, pbc_start)
            match_dirty = slot_active & (tag == addr) & (state1 == DIRTY)
            match_drain = slot_active & (tag == addr) & (state1 == DRAIN)
            has_dirty = jnp.any(match_dirty)
            idx = jnp.argmax(match_dirty)

            is_coalesce = jnp.logical_and(
                scheme == int(Scheme.PB_RF), has_dirty)
            # An in-flight (Drain) older version does NOT block the new
            # persist (write order, Section IV-A): the new version gets its
            # own entry.  The switch->PM path is FIFO per bank, so drains of
            # the same line arrive at PM in version order without waiting
            # for the previous ack.

            empty_mask = slot_active & (state1 == EMPTY)
            any_empty = jnp.any(empty_mask)
            empty_idx = jnp.argmin(jnp.where(empty_mask, lru, INF))
            dirty_mask = slot_active & (state1 == DIRTY)
            any_dirty = jnp.any(dirty_mask)
            victim_idx = jnp.argmin(jnp.where(dirty_mask, lru, INF))
            drain_mask = slot_active & (state1 == DRAIN)
            earliest_idx = jnp.argmin(jnp.where(drain_mask, dd, INF))

            # victim drain (only used when no Empty entry exists)
            victim_bank = tag[victim_idx] % B
            victim_pm_start = jnp.maximum(pm_busy[victim_bank],
                                          pbc_start + sc["ow_sw1_pm"])
            victim_dd = victim_pm_start + sc["nvm_write"] + sc["ow_sw1_pm"]
            needs_victim = (~is_coalesce) & (~any_empty) & any_dirty

            slot = jnp.where(any_empty, empty_idx,
                             jnp.where(any_dirty, victim_idx, earliest_idx))
            ta = jnp.where(any_empty, pbc_start,
                           jnp.where(any_dirty, victim_dd,
                                     jnp.maximum(pbc_start,
                                                 dd[earliest_idx])))
            pm_busy1 = pm_busy.at[victim_bank].set(jnp.where(
                needs_victim, victim_pm_start + sc["nvm_w_occ"],
                pm_busy[victim_bank]))
            state2 = jnp.where(
                needs_victim & (slot_ids == victim_idx), DRAIN, state1)
            dd2 = jnp.where(
                needs_victim & (slot_ids == victim_idx), victim_dd, dd)

            # write the entry (new allocation or coalesce-in-place)
            wslot = jnp.where(is_coalesce, idx, slot)
            t_written = jnp.where(is_coalesce, pbc_start, ta) + sc["data_ns"]
            ack = t_written + sc["ow_cpu_sw1"]
            state3 = jnp.where(slot_ids == wslot, DIRTY, state2)
            tag3 = tag.at[wslot].set(addr)
            lru3 = lru.at[wslot].set(t_written)
            dd3 = dd2

            pm_writes_inc = needs_victim.astype(jnp.float64)
            if scheme == int(Scheme.PB):
                # drain-immediately policy (channel FIFO preserves the
                # version order of same-line drains)
                pm_start2 = jnp.maximum(pm_busy1[bank],
                                        t_written + sc["ow_sw1_pm"])
                dd_new = pm_start2 + sc["nvm_write"] + sc["ow_sw1_pm"]
                state4 = jnp.where(slot_ids == wslot, DRAIN, state3)
                dd4 = dd3.at[wslot].set(dd_new)
                pm_busy2 = pm_busy1.at[bank].set(pm_start2 + sc["nvm_w_occ"])
                pm_writes_inc = pm_writes_inc + 1.0
            else:
                # PB_RF threshold/preset drain-down over LRU Dirty
                # entries, plus a keep-one-free heuristic: if the Empty
                # pool is (nearly) exhausted, drain a couple of LRU Dirty
                # entries pre-emptively so the PI front cannot cascade into
                # head-of-line victim stalls.
                dirty_cnt = jnp.sum((state3 == DIRTY) & slot_active)
                empty_cnt = jnp.sum((state3 == EMPTY) & slot_active)
                do_drain = dirty_cnt >= sc["threshold_count"]
                k_thresh = jnp.where(do_drain,
                                     dirty_cnt - sc["preset_count"], 0.0)
                k_low = jnp.where(empty_cnt <= 1.0,
                                  jnp.minimum(2.0, dirty_cnt), 0.0)
                k = jnp.maximum(k_thresh, k_low)
                key = jnp.where((state3 == DIRTY) & slot_active, lru3, INF)
                rank = jnp.argsort(jnp.argsort(key)).astype(jnp.float64)
                to_drain = (rank < k) & (state3 == DIRTY) & slot_active
                banks = tag3 % B
                # rank among drained entries sharing a bank (serializes the
                # burst per PM bank, overlapping across banks)
                same_bank = banks[:, None] == banks[None, :]
                earlier = rank[None, :] < rank[:, None]
                rank_b = jnp.sum(
                    (same_bank & earlier & to_drain[None, :]).astype(
                        jnp.float64), axis=1)
                start_i = (jnp.maximum(pm_busy1[banks],
                                       t_written + sc["ow_sw1_pm"])
                           + rank_b * sc["nvm_w_occ"])
                dd_j = start_i + sc["nvm_write"] + sc["ow_sw1_pm"]
                state4 = jnp.where(to_drain, DRAIN, state3)
                dd4 = jnp.where(to_drain, dd_j, dd3)
                busy_after = jnp.where(to_drain,
                                       start_i + sc["nvm_w_occ"], 0.0)
                per_bank = jnp.max(
                    jnp.where(same_bank & to_drain[None, :],
                              busy_after[None, :], 0.0), axis=1)
                pm_busy2 = jnp.maximum(
                    pm_busy1,
                    jnp.zeros((B,), jnp.float64).at[banks].max(per_bank))
                pm_writes_inc = pm_writes_inc + k

            stall = jnp.where(is_coalesce, 0.0, ta - pbc_start)
            stats = stats.at[S_VICTIM_CNT].add(
                ((~is_coalesce) & (~any_empty)).astype(jnp.float64))
            stats = stats.at[S_PBCQ_SUM].add(
                jnp.maximum(pbc_busy - arr, 0.0))
            # Only a genuine Empty-shortage stall (ta > pbc_start) holds
            # the PI front beyond the pipelined issue interval.
            pbc_free = jnp.maximum(
                jnp.maximum(pbc_busy, arr) + sc["pbc_occ_ns"],
                jnp.where(is_coalesce | (ta <= pbc_start), 0.0, ta))
            stats = stats.at[S_PERSIST_SUM].add(ack - t)
            stats = stats.at[S_PERSIST_CNT].add(1.0)
            stats = stats.at[S_COALESCES].add(is_coalesce.astype(jnp.float64))
            stats = stats.at[S_PM_WRITES].add(pm_writes_inc)
            stats = stats.at[S_STALL_TIME].add(stall)
            return (clock.at[c].set(ack), ptr, tag3, state4, lru3, dd4,
                    pm_busy2, pbc_free, stats)

        # ---------------- barrier ------------------------------------------
        def br_barrier(a):
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = a
            # centralized barrier over all C cores; the last arrival
            # releases everyone at its arrival time.
            last = (bcount + 1) >= C
            released = jnp.where(blocked, t, clock).at[c].set(t)
            waiting = clock.at[c].set(INF * 0.9)
            return (jnp.where(last, released, waiting), ptr, tag, state,
                    lru, dd, pm_busy, pbc_busy, stats)

        new = jax.lax.switch(
            jnp.clip(op, 0, 5),
            [br_compute, br_dram_read, br_dram_write, br_pm_read,
             br_persist, br_barrier],
            (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats))
        (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy, stats) = new
        is_bar = valid & (op == int(Op.BARRIER))
        last = is_bar & ((bcount + 1) >= C)
        blocked = jnp.where(last, jnp.zeros_like(blocked),
                            jnp.where(is_bar, blocked.at[c].set(True),
                                      blocked))
        bcount = jnp.where(last, 0, jnp.where(is_bar, bcount + 1, bcount))
        ptr = ptr.at[c].add(jnp.where(valid, 1, 0))
        return (clock, ptr, tag, state, lru, dd, pm_busy, pbc_busy,
                blocked, bcount, stats), None

    init = (
        jnp.zeros((C,), jnp.float64),            # clocks
        jnp.zeros((C,), jnp.int32),              # ptrs
        jnp.full((max_pbe,), -1, jnp.int32),     # TAT tags
        jnp.full((max_pbe,), EMPTY, jnp.int32),  # ST states
        jnp.zeros((max_pbe,), jnp.float64),      # LRU stamps
        jnp.zeros((max_pbe,), jnp.float64),      # drain-ack times
        jnp.zeros((B,), jnp.float64),            # PM bank next-free times
        jnp.zeros((), jnp.float64),              # PBC next-free
        jnp.zeros((C,), bool),                   # blocked at barrier
        jnp.zeros((), jnp.int32),                # barrier arrival count
        jnp.zeros((N_STATS,), jnp.float64),
    )
    final, _ = jax.lax.scan(step, init, None, length=n_steps)
    clock = final[0]
    stats = final[-1]
    runtime = jnp.max(jnp.where(clock < INF * 0.5, clock, 0.0))
    return runtime, stats


_BUCKET = 16384


def _pad_up(n: int, b: int = _BUCKET) -> int:
    return ((max(n, 1) + b - 1) // b) * b


def _padded_arrays(trace: Trace):
    """Pad trace arrays / step counts to bucket sizes so workloads of
    similar size share one compiled program (jit keys on shapes)."""
    C, L = trace.ops.shape
    Lp = _pad_up(L)
    ops = np.zeros((C, Lp), np.int32)
    addrs = np.zeros((C, Lp), np.int32)
    gaps = np.zeros((C, Lp), np.float32)
    ops[:, :L] = trace.ops
    addrs[:, :L] = trace.addrs
    gaps[:, :L] = trace.gaps
    return ops, addrs, gaps, trace.lengths, _pad_up(trace.total_ops)


def simulate(trace: Trace, config: PCSConfig, max_pbe: int | None = None
             ) -> SimResult:
    """Simulate one (trace, config) pair and return aggregate metrics."""
    max_pbe = max_pbe or config.n_pbe
    if config.n_pbe > max_pbe:
        raise ValueError("n_pbe exceeds max_pbe")
    sc_np = _scalars_from_config(config)
    ops, addrs, gaps, lengths, n_steps = _padded_arrays(trace)
    with jax.enable_x64(True):
        sc = {k: jnp.asarray(v, jnp.float64) for k, v in sc_np.items()}
        runtime, stats = _simulate(
            jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(gaps),
            jnp.asarray(lengths), sc,
            scheme=int(config.scheme), max_pbe=max_pbe, n_steps=n_steps,
            pm_banks=config.pm_banks)
        runtime = float(runtime)
        stats = np.asarray(stats)
    return _result(runtime, stats)


def _result(runtime: float, stats: np.ndarray) -> SimResult:
    return SimResult(
        runtime_ns=runtime,
        persist_lat_ns=float(stats[S_PERSIST_SUM] / max(stats[S_PERSIST_CNT], 1)),
        read_lat_ns=float(stats[S_READ_SUM] / max(stats[S_READ_CNT], 1)),
        persists=int(stats[S_PERSIST_CNT]),
        pm_reads=int(stats[S_READ_CNT]),
        read_hits=int(stats[S_READ_HITS]),
        coalesces=int(stats[S_COALESCES]),
        pm_writes=int(stats[S_PM_WRITES]),
        stall_ns=float(stats[S_STALL_TIME]),
        pi_detours=int(stats[S_PI_DETOURS]),
    )


def simulate_sweep(trace: Trace, configs: List[PCSConfig]) -> List[SimResult]:
    """vmap one trace over many configs sharing a scheme (Fig. 1 / Fig. 8).

    All latency scalars are batched; scheme and the padded PBE capacity are
    shared statics, so the whole sweep is a single compiled program.
    """
    if not configs:
        return []
    scheme = configs[0].scheme
    if any(c.scheme != scheme for c in configs):
        raise ValueError("sweep configs must share a scheme")
    max_pbe = max(c.n_pbe for c in configs)
    rows = [_scalars_from_config(c) for c in configs]
    ops, addrs, gaps, lengths, n_steps = _padded_arrays(trace)
    with jax.enable_x64(True):
        sc = {k: jnp.asarray([r[k] for r in rows], jnp.float64) for k in rows[0]}
        fn = jax.vmap(
            lambda s: _simulate(
                jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(gaps),
                jnp.asarray(lengths), s,
                scheme=int(scheme), max_pbe=max_pbe, n_steps=n_steps,
                pm_banks=configs[0].pm_banks))
        runtimes, stats = fn(sc)
        runtimes = np.asarray(runtimes)
        stats = np.asarray(stats)
    return [_result(float(runtimes[i]), stats[i]) for i in range(len(configs))]
