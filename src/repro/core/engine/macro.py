"""Macro-stepping: execute a homogeneous op run as one guarded step.

The trace-time pre-pass (``core.traces.plan_runs``) marks, per trace
slot, the length of the longest *statically eligible* run starting
there: consecutive PM_READ / PERSIST ops of one core with non-negative
gaps and pairwise-distinct addresses (when a persist is involved).  The
step driver (``engine.step``) consults that plan and hands eligible
windows to :func:`macro_step`, which replays up to ``MACRO_KMAX`` ops of
the selected core as an *unrolled exact mini-interpreter* — every
arithmetic expression is kept in the same form and order as the
slot-at-a-time handlers, so a committed macro-step is bit-identical to
the handler path by construction, not by approximation.

Commit-or-abort contract (the SyphonArch trace-speculation shape —
record a hot linear path, guard it, fall back on guard failure):

  * while replaying, the mini-interpreter accumulates a traced guard
    conjunction; any op that would leave the straight-line fast path —
    a PB lookup hit, a coalesce opportunity, a missing Empty slot, a
    PB_RF drain-down that would fire, an op issuing past the crash
    point, a deep (>= 2 switch) chain cell — clears the guard;
  * cross-core interleaving is guarded globally: every other core's
    next issue time must lie strictly after the window's last issue
    time, so the engine's argmin selection provably picks this core
    for the whole window;
  * on guard failure the whole candidate state is discarded (commit-
    or-abort, never a partial prefix) and the driver's slot-at-a-time
    result stands; the run re-enters macro planning at the next step.

A second, independent fast path collapses *dead runs*: once a core's
next op issues after the crash point, its remaining stream drains as
provable no-ops that only advance its cursor and clock — those are
collapsed ``MACRO_KMAX`` at a time with no guard beyond gap
non-negativity (dead ops touch no shared state, so they commute with
every other core's ops bit-exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import channels, fabric, policy
from repro.core.engine.state import (DIRTY, EMPTY, INF, H_FWD_CNT, H_FWD_SUM,
                                     S_ACKED, S_DURABLE, S_LAT_HIST0,
                                     S_PBCQ_SUM, S_PERSIST_CNT,
                                     S_PERSIST_SUM, S_PM_WRITES, S_READ_CNT,
                                     S_READ_SUM, S_SLO_OVER, lat_bin)
from repro.core.params import Op

# Prioritized abort attribution for live candidate windows: each live op
# at the head of a window that fails to commit counts under exactly the
# *first* failing gate, in this order.  ``window`` = no eligible >= 2-op
# run at the cursor; ``fabric`` = a multi-leaf fabric cell (the
# mini-interpreter models neither leaf scoping nor spine backpressure);
# ``deep`` = a >= 2-switch chain cell; ``epoch_boundary`` = the window
# straddles an epoch boundary of a scheduled config (the
# mini-interpreter replays every op under the head op's epoch, so a
# mid-window epoch switch must fall back to the slot-at-a-time path);
# ``interleave`` = another core issues inside the window; ``guard`` =
# the in-window traced guard conjunction cleared (PB hit, coalesce,
# drain-down fired, ...).  The vector returned by :func:`macro_step` is
# summed across steps/cells by ``engine.grid`` and surfaced via
# ``last_macro_abort_reasons()``.
MACRO_ABORT_REASONS = ("window", "fabric", "deep", "epoch_boundary",
                       "interleave", "guard")


def macro_step(ctx, st, ops, addrs, gaps64, lengths, mlen, tsel,
               valid, live, t_issue, i, *, kmax: int,
               next_epoch_bound=None):
    """Candidate macro execution of up to ``kmax`` ops of core ``ctx.c``.

    Returns ``(st_macro, use_macro, k_adv, abort_vec)``: the candidate
    state (only meaningful where ``use_macro`` holds), whether either
    macro path (live window or dead run) committed, how many trace
    slots it consumed, and the one-hot ``MACRO_ABORT_REASONS`` vector
    (all-zero when the window committed or no live candidate existed).
    The caller selects ``st_macro`` over the slot-step result and
    advances the cursor by ``k_adv`` when ``use_macro`` is set.

    ``next_epoch_bound`` is the first epoch boundary strictly after the
    head op's issue time in an epoch-scheduled grid (``INF`` inside the
    last epoch), or ``None`` for single-epoch grids.  ``ctx.sc`` is the
    epoch-resolved view at the head op's issue time; the window commits
    only when its last issue time still precedes the boundary, i.e.
    every replayed op provably shares the head op's epoch (the
    ``epoch_boundary`` abort reason counts the windows this rejects).
    Dead runs are exempt: dead ops touch no policy state, so an epoch
    switch inside a collapsed post-crash stream changes nothing.
    """
    sc = ctx.sc
    c = ctx.c
    crash = sc["crash_at"]
    A = st.aver.shape[0]
    T = st.stats.shape[0]

    # window data; the grid pads L by kmax slots so the slice never
    # clamps (see grid._stack_traces)
    c32 = c.astype(jnp.int32)
    i32 = i.astype(jnp.int32)
    w_ops = jax.lax.dynamic_slice(ops, (c32, i32), (1, kmax))[0]
    w_addr = jax.lax.dynamic_slice(addrs, (c32, i32), (1, kmax))[0]
    w_gap = jax.lax.dynamic_slice(gaps64, (c32, i32), (1, kmax))[0]
    rem = lengths[c] - i
    k_cap = jnp.clip(rem, 0, kmax)

    # ---------------- dead-run collapse (post-crash stream drain) ------
    # Each dead step sets clock[c] to its issue time and bumps the
    # cursor; the sequential masked adds reproduce the step-at-a-time
    # rounding order exactly.  Monotone issue times (gaps >= 0) make
    # first-dead imply all-dead.
    gaps_ok = jnp.all(w_gap >= 0.0)
    clk_dead, _ = jax.lax.scan(
        lambda ck, jg: (jnp.where(jg[0] < k_cap, ck + jg[1], ck), None),
        st.clock[c], (jnp.arange(kmax), w_gap))
    dead_ok = valid & ~live & gaps_ok & (k_cap >= 2)
    st_dead = st._replace(clock=st.clock.at[c].set(clk_dead))

    # ---------------- live window (exact mini-interpreter) -------------
    k_live = jnp.minimum(mlen[c, i].astype(jnp.int32), k_cap)
    is_nopb = ctx.scheme == 0                       # Scheme.NOPB
    is_rf = ctx.scheme == 2                         # Scheme.PB_RF
    pb_like = ~is_nopb
    # chain cells (>= 2 switches) take the deep persist/read legs the
    # mini-interpreter does not model; their dead tails still collapse
    deep_ok = is_nopb | (sc["n_switches"] < 2.0)
    # multi-leaf fabric cells additionally scope hop-1 state to the
    # issuing tenant's leaf and may defer drains on spine backpressure —
    # neither is modelled here (a fabric forces n_switches = 2, so
    # deep_ok already aborts these; fab_ok attributes the abort)
    fab_ok = is_nopb | (sc["n_leaves"] < 2.0)
    # per-leaf PBC clocks: in a grid carrying the fabric axis the
    # handlers serve hop-1 PBC time from lpbc[leaf(tenant)], so the
    # mini-interpreter must read/write the same cell (the window's
    # tenant — hence its leaf — is constant, and non-fabric cells
    # lower leaf_of_t = 0)
    NL = st.lpbc.shape[0]
    if NL > 0:
        my_leaf = fabric.leaf_of_tenant(sc, ctx.tenant)
        pbc0 = st.lpbc[my_leaf]
    else:
        pbc0 = st.pbc_busy

    ow = sc["ow_cpu_pm"]

    # The window replay is a lax.scan over the kmax slots (not a Python
    # unroll): every iteration runs the identical expressions in
    # sequence, so the result is bitwise the same as unrolling while the
    # op body lowers to ONE XLA subgraph instead of kmax inlined copies
    # (the scan body already dominates compile time; unrolling the
    # mini-interpreter 8x on top of it roughly doubled it again).
    def win_op(carry, x):
        (clk, state_cur, tag_cur, lru_cur, dd_cur, ver_cur, owner_cur,
         pmb_cur, pbc_cur, pm_ver_cur, aver_cur, stats_cur, hop_cur,
         guard, t_last) = carry
        j, o_j, a_j, g_j = x
        m = j < k_live
        is_p = o_j == int(Op.PERSIST)
        t_j = clk + g_j
        t_last = jnp.where(m, t_j, t_last)
        bank = channels.bank_of(a_j, ctx.n_banks)
        tracked = (a_j >= 0) & (a_j < ctx.n_track)
        a_idx = jnp.clip(a_j, 0, A - 1)

        # ---- PM read (handler miss path; identical in both schemes)
        pm_start_r = channels.service_start(pmb_cur, bank, t_j + ow)
        resp = pm_start_r + sc["nvm_read"] + ow
        state_rd = policy.lazy_free(state_cur, dd_cur, t_j)
        has_rd = jnp.any(ctx.slot_active & (tag_cur == a_j)
                         & (state_rd != EMPTY))
        pmb_rd = pmb_cur.at[bank].set(pm_start_r + sc["nvm_r_occ"])

        # ---- persist, NoPB leg (always exact: no guard)
        pm_start_w = channels.service_start(pmb_cur, bank, t_j + ow)
        ack_n = pm_start_w + sc["nvm_write"] + ow
        ok_n = ack_n <= crash
        pmb_wn = channels.reserve(pmb_cur, bank, pm_start_w,
                                  sc["nvm_w_occ"])

        # ---- persist, buffered leg (fresh-Empty allocation only)
        arr = t_j + sc["ow_cpu_sw1"]
        pbc_start = channels.pbc_start(pbc_cur, arr,
                                       sc["pbc_proc_ns"] + sc["tag_ns"])
        state_p1 = policy.lazy_free(state_cur, dd_cur, pbc_start)
        has_dirty = jnp.any(ctx.slot_active & (tag_cur == a_j)
                            & (state_p1 == DIRTY))
        # select_slot's Empty leg under the quota gate, verbatim
        occ_t = jnp.sum(jnp.where(
            ctx.slot_active & (state_p1 != EMPTY)
            & (jnp.clip(owner_cur, 0, T - 1) == ctx.tenant), 1.0, 0.0))
        over_quota = occ_t >= sc["quota"][ctx.tenant]
        empty_mask = ctx.slot_active & (state_p1 == EMPTY) & ~over_quota
        any_empty = jnp.any(empty_mask)
        wslot = jnp.argmin(jnp.where(empty_mask, lru_cur, INF))
        t_written = pbc_start + sc["data_ns"]
        ack_p = t_written + sc["ow_cpu_sw1"]
        v_new = aver_cur[a_idx] + 1
        state_w = jnp.where(ctx.slot_ids == wslot, DIRTY, state_p1)
        tag_w = tag_cur.at[wslot].set(a_j)
        lru_w = lru_cur.at[wslot].set(t_written)
        ver_w = ver_cur.at[wslot].set(v_new)
        owner_w = owner_cur.at[wslot].set(
            ctx.tenant.astype(owner_cur.dtype))
        # PB: immediate drain of the written entry (exact policy call)
        st4_pb, dd4_pb, pmb2_pb, _pw = policy.drain_immediate(
            sc, bank, ctx.slot_ids, wslot, t_written, state_w, dd_cur,
            pmb_cur)
        dd_new_pb = dd4_pb[wslot]
        # PB_RF: guard that the threshold/preset drain-down fires zero
        # drains (same sub-expressions as drain_threshold_preset's k)
        scoped = sc["drain_scope"] > 0.0
        in_scope = jnp.where(scoped, owner_w == ctx.tenant, True)
        dirty_cnt = jnp.sum((state_w == DIRTY) & ctx.slot_active
                            & in_scope)
        empty_cnt = jnp.sum((state_w == EMPTY) & ctx.slot_active)
        thr = jnp.where(scoped, sc["t_threshold"][ctx.tenant],
                        sc["threshold_count"])
        pre = jnp.where(scoped, sc["t_preset"][ctx.tenant],
                        sc["preset_count"])
        # serving-SLO tightening mirror (handler computes tight from the
        # pre-op stats row *including this persist*; with no target the
        # lowered scalar is INF, over stays 0 and tight is never true)
        lat_p = ack_p - t_j
        over_p = (lat_p > sc["lat_target"]).astype(jnp.float64)  # lint: mirror(slo-over)
        cnt1 = stats_cur[ctx.tenant, S_PERSIST_CNT] + 1.0  # lint: mirror(slo-cnt)
        over1 = stats_cur[ctx.tenant, S_SLO_OVER] + over_p  # lint: mirror(slo-run)
        tight = over1 > sc["lat_tol"] * cnt1  # lint: mirror(slo-tight)
        thr = jnp.where(tight, 1.0, thr)  # lint: mirror(rf-tight-thr)
        pre = jnp.where(tight, 0.0, pre)  # lint: mirror(rf-tight-pre)
        do_drain = dirty_cnt >= thr  # lint: mirror(rf-do-drain)
        k_thresh = jnp.where(do_drain, dirty_cnt - pre, 0.0)  # lint: mirror(rf-k-thresh)
        k_low = jnp.where(empty_cnt <= sc["empty_slack"],  # lint: mirror(rf-k-low)
                          jnp.minimum(sc["low_water"], dirty_cnt), 0.0)
        rf_zero = jnp.maximum(k_thresh, k_low) == 0.0
        # scheme-selected buffered outcome (RF with k == 0 is a no-op
        # drain policy: state/dd/pm_busy provably unchanged)
        state_wp = jnp.where(is_rf, state_w, st4_pb)
        dd_wp = jnp.where(is_rf, dd_cur, dd4_pb)
        pmb_wp = jnp.where(is_rf, pmb_cur, pmb2_pb)
        pbcq_inc = jnp.maximum(pbc_cur - arr, 0.0)
        pbc_wp = jnp.maximum(
            channels.pbc_hold(pbc_cur, arr, sc["pbc_occ_ns"]), 0.0)

        # ---- per-op guard
        g_wr = (any_empty & (t_written <= crash)
                & (~is_rf | (~has_dirty & rf_zero)))
        g_op = ((t_j <= crash)
                & jnp.where(pb_like, jnp.where(is_p, g_wr, ~has_rd), True))
        guard = guard & jnp.where(m, g_op, True)

        # ---- apply op j (masked; aborted windows are discarded whole)
        sel_r = m & ~is_p
        sel_wn = m & is_p & is_nopb
        sel_wp = m & is_p & pb_like
        clk = jnp.where(
            m, jnp.where(is_p, jnp.where(is_nopb, ack_n, ack_p), resp),
            clk)
        state_cur = jnp.where(sel_wp, state_wp,
                              jnp.where(sel_r & pb_like, state_rd,
                                        state_cur))
        tag_cur = jnp.where(sel_wp, tag_w, tag_cur)
        lru_cur = jnp.where(sel_wp, lru_w, lru_cur)
        ver_cur = jnp.where(sel_wp, ver_w, ver_cur)
        owner_cur = jnp.where(sel_wp, owner_w, owner_cur)
        dd_cur = jnp.where(sel_wp, dd_wp, dd_cur)
        pmb_cur = jnp.where(sel_r, pmb_rd,
                            jnp.where(sel_wn, pmb_wn,
                                      jnp.where(sel_wp, pmb_wp, pmb_cur)))
        pbc_cur = jnp.where(sel_wp, pbc_wp, pbc_cur)
        aver_cur = aver_cur.at[a_idx].add(
            jnp.where(m & is_p & tracked, 1, 0))
        pv_ok = jnp.where(is_nopb, ok_n, ~is_rf & (dd_new_pb <= crash))
        pm_ver_cur = pm_ver_cur.at[a_idx].max(
            jnp.where(m & is_p & tracked & pv_ok, v_new, 0))
        # stats / telemetry: adds of exact 0.0 are bitwise identities
        # (every counter is >= +0.0), so skipped terms stay exact.  The
        # per-persist latency histogram + SLO-over counter use identical
        # expressions to the handler sites (lat = scheme-selected ack -
        # issue time); masked lanes add exact 0.0 at a garbage bin,
        # which is a bitwise identity.  One fused scatter per window
        # step (all columns distinct) keeps every per-column sum
        # element-wise identical to the chained adds.
        # lint: exempt(stats-columns, S_COALESCES S_READ_HITS S_PI_DETOURS): guard aborts PB-hit/coalesce windows
        # lint: exempt(stats-columns, S_STALL_TIME S_VICTIM_CNT): guard aborts stall/eviction windows
        lat_j = jnp.where(is_nopb, ack_n, ack_p) - t_j
        over_j = (lat_j > sc["lat_target"]).astype(jnp.float64)  # lint: mirror(slo-over)
        hist_col = (S_LAT_HIST0 + lat_bin(lat_j))[None]  # lint: mirror(lat-bin)
        scols = jnp.concatenate([
            jnp.asarray([S_READ_SUM, S_READ_CNT, S_PBCQ_SUM,
                         S_PERSIST_SUM, S_PERSIST_CNT, S_SLO_OVER,
                         S_PM_WRITES, S_ACKED, S_DURABLE], jnp.int32),
            hist_col])
        svals = jnp.stack([
            jnp.where(sel_r, resp - t_j, 0.0),
            jnp.where(sel_r, 1.0, 0.0),
            jnp.where(sel_wp, pbcq_inc, 0.0),
            jnp.where(m & is_p,
                      jnp.where(is_nopb, ack_n, ack_p) - t_j, 0.0),
            jnp.where(m & is_p, 1.0, 0.0),
            jnp.where(m & is_p, over_j, 0.0),
            jnp.where(m & is_p & (is_nopb | ~is_rf), 1.0, 0.0),
            jnp.where(m & is_p,
                      jnp.where(is_nopb, ok_n, ack_p <= crash)
                      .astype(jnp.float64), 0.0),
            jnp.where(m & is_p,
                      jnp.where(is_nopb, ok_n.astype(jnp.float64), 1.0),
                      0.0),
            jnp.where(m & is_p, 1.0, 0.0)])
        stats_cur = stats_cur.at[ctx.tenant, scols].add(svals)  # lint: mirror(stats-scatter)
        hop_cur = hop_cur.at[
            0, jnp.asarray([H_FWD_CNT, H_FWD_SUM], jnp.int32)
        ].add(jnp.stack([jnp.where(sel_wp, 1.0, 0.0),
                         jnp.where(sel_wp, t_written - arr, 0.0)]))
        return (clk, state_cur, tag_cur, lru_cur, dd_cur, ver_cur,
                owner_cur, pmb_cur, pbc_cur, pm_ver_cur, aver_cur,
                stats_cur, hop_cur, guard, t_last), None

    carry0 = (st.clock[c], st.state, st.tag, st.lru, st.dd, st.ver,
              st.owner, st.pm_busy, pbc0, st.pm_ver, st.aver,
              st.stats, st.hop_stats, jnp.asarray(True), t_issue)
    (clk, state_cur, tag_cur, lru_cur, dd_cur, ver_cur, owner_cur,
     pmb_cur, pbc_cur, pm_ver_cur, aver_cur, stats_cur, hop_cur,
     guard, t_last), _ = jax.lax.scan(
        win_op, carry0, (jnp.arange(kmax), w_ops, w_addr, w_gap))

    # no other core may issue inside the window (strict: argmin ties
    # break by index, so equality must abort too)
    others_min = jnp.min(tsel.at[c].set(INF))
    no_ilv = others_min > t_last
    # epoch-scheduled grids: the whole window must live in the head
    # op's epoch (boundary instants belong to the *next* epoch, so the
    # last issue time must be strictly below the next boundary)
    if next_epoch_bound is None:
        ep_ok = jnp.asarray(True)
    else:
        ep_ok = t_last < next_epoch_bound
    live_ok = (valid & live & (k_live >= 2) & fab_ok & deep_ok & ep_ok
               & guard & no_ilv)

    # prioritized abort attribution (MACRO_ABORT_REASONS order): each
    # live candidate that failed to commit counts exactly one reason
    cand = valid & live
    elig = cand & (k_live >= 2)
    abort_vec = jnp.stack([
        cand & (k_live < 2),
        elig & ~fab_ok,
        elig & fab_ok & ~deep_ok,
        elig & fab_ok & deep_ok & ~ep_ok,
        elig & fab_ok & deep_ok & ep_ok & ~no_ilv,
        elig & fab_ok & deep_ok & ep_ok & no_ilv & ~guard,
    ]).astype(jnp.int32)

    if NL > 0:
        pbc_kw = dict(lpbc=st.lpbc.at[my_leaf].set(pbc_cur))
    else:
        pbc_kw = dict(pbc_busy=pbc_cur)
    st_live = st._replace(
        clock=st.clock.at[c].set(clk), state=state_cur, tag=tag_cur,
        lru=lru_cur, dd=dd_cur, ver=ver_cur, owner=owner_cur,
        aver=aver_cur, pm_ver=pm_ver_cur, pm_busy=pmb_cur,
        stats=stats_cur, hop_stats=hop_cur, **pbc_kw)

    use_macro = live_ok | dead_ok
    k_adv = jnp.where(live_ok, k_live, k_cap)
    st_macro = jax.tree_util.tree_map(
        lambda a, b: jnp.where(live_ok, a, b), st_live, st_dead)
    return st_macro, use_macro, k_adv, abort_vec
