"""Resource model: PM device banks and the PBC service port.

Every shared resource is a scalar "next-free time".  A requester that
arrives at ``ready`` starts service at ``max(next_free, ready)`` and
holds the resource for its *occupancy* (device-internal pipelining lets
a PM bank accept the next request before the requester observes its
response, so occupancy < latency).

The PBC is a single FIFO front: persists and PI-routed reads serialize
on ``pbc_busy``; the head-of-line blocking of reads behind stalled
writes (the paper's Fig. 6b mechanism) falls out of this scalar.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INF = 1e30  # engine.state.INF (kept local: state imports no channels)


def bank_of(addr, n_banks: int):
    """Static interleave of cache lines across independent PM banks."""
    return addr % n_banks


def service_start(busy, bank, ready):
    """When bank ``bank`` can begin serving a request arriving at ``ready``."""
    return jnp.maximum(busy[bank], ready)


def reserve(busy, bank, start, occ):
    """Hold the bank from ``start`` for ``occ`` ns; returns updated vector."""
    return busy.at[bank].set(start + occ)


def pbc_start(pbc_busy, arrival, proc_ns):
    """PBC FIFO service start + processing for one packet."""
    return jnp.maximum(pbc_busy, arrival) + proc_ns


def pbc_hold(pbc_busy, arrival, occ_ns):
    """Advance the PBC next-free time past one packet's issue interval."""
    return jnp.maximum(pbc_busy, arrival) + occ_ns


def fifo_service(busy, arrivals, active, occ_ns):
    """Batch FIFO service of a deep-hop PBC / inter-switch channel.

    ``arrivals`` (Q,) are packet arrival times in channel order (batch
    order == wire order); ``active`` masks live packets.  Service start
    of packet q is ``max(arrival_q, start_{q-1} + occ)`` with the
    channel busy until ``busy`` — the standard FIFO recurrence, solved
    in closed form with a cumulative max:

        start_q = occ*rank_q + max(busy, max_{i<=q}(arr_i - occ*rank_i))

    Returns ``(starts (Q,), busy_after ())``; inactive packets get INF
    starts and do not advance the channel.
    """
    rank = jnp.cumsum(active.astype(jnp.float64)) - 1.0
    adj = jnp.where(active, arrivals - occ_ns * rank, -_INF)
    run = jax.lax.cummax(adj)
    starts = jnp.where(active,
                       occ_ns * rank + jnp.maximum(run, busy), _INF)
    busy_after = jnp.max(jnp.where(active, starts + occ_ns, busy))
    return starts, jnp.maximum(busy_after, busy)
