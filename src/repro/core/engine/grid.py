"""Batched (trace x config x scheme) front-end and compatibility wrappers.

``simulate_grid`` runs the paper's whole evaluation grid as one XLA
program: traces are padded into shared (C, L) buckets and stacked on a
leading axis, configs are lowered to stacked latency/policy scalars plus
a traced scheme id, and the cell program (``engine.step.scan_cell``) is
nested-``vmap``-ed over the config axis then the trace axis.  Mixed
schemes in one grid are first-class — the scheme is traced, not a
compile-time static.

``simulate_cells`` is the flat variant: one result per (trace, config)
*pair* under a single vmap axis, for sweeps that never needed the full
cross product (half the cells of an anchored two-trace sweep).

The stacker also runs the macro-run pre-pass (``core.traces.plan_runs``)
and pads the op axis by ``MACRO_KMAX`` slots so the engine's macro-step
window slice never clamps; ``macro=False`` opts a call out (the
differential tests' control column).  Input buffers are donated to the
jitted programs — they are freshly staged per call, so XLA may reuse
them for the scan carry instead of allocating.

``simulate`` and ``simulate_sweep`` are thin compatibility wrappers over
the same cell program, returning identical ``SimResult`` objects to the
original monolithic simulator.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.engine.macro import MACRO_ABORT_REASONS
from repro.core.engine.state import (SimResult, result_from_stats,
                                     scalars_from_config)
from repro.core.engine.step import scan_cell
from repro.core.params import MACRO_KMAX, PCSConfig
from repro.core.traces import Trace, plan_runs

_BUCKET = 16384

# telemetry of the most recent grid/cells call: macro-executed trace
# slots vs total trace slots (the benchmarks' macro_hit_rate source),
# plus the per-reason counts of live macro windows that failed to
# commit (MACRO_ABORT_REASONS order, summed over all cells)
_LAST_MACRO = {"macro_ops": 0, "total_ops": 0,
               "abort_reasons": [0] * len(MACRO_ABORT_REASONS)}


def last_macro_hit_rate() -> float:
    """Fraction of trace slots the latest simulate_* call ran via
    macro-steps (0.0 when macro was disabled or nothing ran)."""
    total = _LAST_MACRO["total_ops"]
    return (_LAST_MACRO["macro_ops"] / total) if total else 0.0


def last_macro_abort_reasons() -> dict:
    """Per-reason counts of live macro candidates the latest simulate_*
    call failed to commit, keyed by ``MACRO_ABORT_REASONS`` name (all
    zero when macro was disabled or nothing ran).  Emitted next to
    ``*_macro_hit`` in BENCH_engine.json so a hit-rate regression can be
    attributed to a guard instead of bisected blind."""
    return dict(zip(MACRO_ABORT_REASONS, _LAST_MACRO["abort_reasons"]))


def _pad_up(n: int, b: int = _BUCKET) -> int:
    return ((max(n, 1) + b - 1) // b) * b


def _stack_traces(traces: Sequence[Trace], bucket: int):
    """Pad traces into one shared (C, L) bucket and stack them.

    Padded cores get zero-length streams (they never issue an op and
    never count toward barriers); padded steps are no-ops, so sharing
    one bucket across workloads of different sizes changes no result.
    The op axis carries ``MACRO_KMAX`` slots of slack past the longest
    stream (inside the bucket rounding) so the macro-step window slice
    never clamps, and the macro-run plan is stacked alongside.
    """
    C = max(t.ops.shape[0] for t in traces)
    L = _pad_up(max(t.ops.shape[1] for t in traces) + MACRO_KMAX, bucket)
    T = len(traces)
    ops = np.zeros((T, C, L), np.int32)
    addrs = np.zeros((T, C, L), np.int32)
    gaps = np.zeros((T, C, L), np.float32)
    lengths = np.zeros((T, C), np.int32)
    for k, t in enumerate(traces):
        c, l = t.ops.shape
        ops[k, :c, :l] = t.ops
        addrs[k, :c, :l] = t.addrs
        gaps[k, :c, :l] = t.gaps
        lengths[k, :c] = t.lengths
    mlen = np.stack([plan_runs(ops[k], addrs[k], gaps[k], MACRO_KMAX)
                     for k in range(T)])
    n_steps = _pad_up(max(t.total_ops for t in traces), bucket)
    return ops, addrs, gaps, lengths, mlen, n_steps


def _stack_configs(configs: Sequence[PCSConfig], max_pbe: int | None,
                   n_tenants_max: int):
    # the static PBE bound must cover every hop of every chain (deep
    # rows share the slot axis with hop 1)
    max_pbe = max_pbe or max(c.max_hop_pbe for c in configs)
    if any(c.max_hop_pbe > max_pbe for c in configs):
        raise ValueError("n_pbe exceeds max_pbe")
    banks = {c.pm_banks for c in configs}
    if len(banks) != 1:
        raise ValueError("grid configs must share pm_banks (array shape)")
    # deep-hop rows are a static shape; only PB-bearing configs need
    # them (a deep NOPB chain is pure wire), and a depth-<=1-only grid
    # lowers to the chain-free program (n_deep == 0)
    n_deep = max((len(c.hop_pbes) - 1 for c in configs), default=0)
    n_deep = max(n_deep, 0)
    # the fabric leaf axis is a static shape too: 1 (no fabric cell in
    # the grid) keeps the per-leaf PBC column empty and the whole fabric
    # layer out of the traced program
    n_leaves = max((c.fabric.n_leaves if c.fabric is not None else 1
                    for c in configs), default=1)
    # the epoch axis is a static shape shared grid-wide: a schedule-free
    # grid lowers the flat single-epoch dict (byte-identical program),
    # while any scheduled config promotes every config's EPOCH_KEYS rows
    # to the grid-wide epoch bound (static configs broadcast their one
    # row; short schedules clamp to their last epoch)
    n_epochs = max((c.n_epochs for c in configs), default=1)
    # policy lowering pads its per-tenant vectors to the grid-wide
    # n_tenants_max, so mixed tenant counts / policies stack into one
    # (K,) or (K, T) array per scalar and share the program
    rows = [scalars_from_config(c, n_tenants_max, n_deep, n_leaves,
                                n_epochs_max=n_epochs)
            for c in configs]
    sc = {k: np.asarray([r[k] for r in rows], np.float64) for k in rows[0]}
    schemes = np.asarray([int(c.scheme) for c in configs], np.int32)
    return sc, schemes, max_pbe, banks.pop(), n_deep, n_leaves


_STATICS = ("max_pbe", "n_steps", "pm_banks", "n_track", "n_tenants_max",
            "n_deep_max", "n_leaves_max", "macro")
_DONATED = ("ops", "addrs", "gaps", "mlen")


@functools.partial(jax.jit, static_argnames=_STATICS,
                   donate_argnames=_DONATED)
def _run_cell(ops, addrs, gaps, lengths, mlen, scheme, sc, *,
              max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
              n_deep_max, n_leaves_max, macro):
    # single-cell program: no batch axes, so `lax.switch` lowers to real
    # branches instead of vmap's execute-all-and-select
    return scan_cell(ops, addrs, gaps, lengths, scheme, sc,
                     max_pbe=max_pbe, n_steps=n_steps, pm_banks=pm_banks,
                     n_track=n_track, n_tenants_max=n_tenants_max,
                     n_deep_max=n_deep_max, n_leaves_max=n_leaves_max,
                     mlen=mlen, macro=macro)


def _cell_fn(max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
             n_deep_max, n_leaves_max, macro):
    def cell(ops, addrs, gaps, lengths, mlen, scheme, sc):
        return scan_cell(ops, addrs, gaps, lengths, scheme, sc,
                         max_pbe=max_pbe, n_steps=n_steps,
                         pm_banks=pm_banks, n_track=n_track,
                         n_tenants_max=n_tenants_max,
                         n_deep_max=n_deep_max, n_leaves_max=n_leaves_max,
                         mlen=mlen, macro=macro)
    return cell


@functools.partial(jax.jit, static_argnames=_STATICS,
                   donate_argnames=_DONATED)
def _run_grid(ops, addrs, gaps, lengths, mlen, schemes, sc, *,
              max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
              n_deep_max, n_leaves_max, macro):
    cell = _cell_fn(max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
                    n_deep_max, n_leaves_max, macro)
    over_cfg = jax.vmap(cell, in_axes=(None, None, None, None, None, 0, 0))
    over_tr = jax.vmap(over_cfg, in_axes=(0, 0, 0, 0, 0, None, None))
    return over_tr(ops, addrs, gaps, lengths, mlen, schemes, sc)


@functools.partial(jax.jit, static_argnames=_STATICS,
                   donate_argnames=_DONATED)
def _run_cells(ops, addrs, gaps, lengths, mlen, schemes, sc, *,
               max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
               n_deep_max, n_leaves_max, macro):
    # flat pairing: one shared batch axis over traces AND configs
    cell = _cell_fn(max_pbe, n_steps, pm_banks, n_track, n_tenants_max,
                    n_deep_max, n_leaves_max, macro)
    return jax.vmap(cell)(ops, addrs, gaps, lengths, mlen, schemes, sc)


def _results_from(out, traces, configs, track_addrs, pairs: bool):
    (runtimes, stats, durable_ver, n_recov, recov_ns, recov_t,
     hop_stats, recov_h, recov_l, mops, maborts) = out
    _LAST_MACRO["macro_ops"] = int(np.sum(mops))
    _LAST_MACRO["total_ops"] = int(sum(t.total_ops for t in traces)
                                   * (1 if pairs else len(configs)))
    _LAST_MACRO["abort_reasons"] = [
        int(x) for x in np.sum(
            np.asarray(maborts).reshape(-1, len(MACRO_ABORT_REASONS)),
            axis=0)]

    def cell(i, j, k):
        fab = configs[j].fabric
        return result_from_stats(
            float(runtimes[k]), stats[k],
            crash_at_ns=configs[j].crash_at_ns,
            recovery_entries=int(n_recov[k]),
            recovery_ns=float(recov_ns[k]),
            durable_ver=(durable_ver[k][:track_addrs].copy()
                         if track_addrs > 0 else None),
            n_tenants=configs[j].n_tenants,
            tenant_recovery=recov_t[k],
            n_hops=len(configs[j].hop_pbes),
            hop_stats=hop_stats[k],
            hop_recovery=recov_h[k],
            n_leaves=fab.n_leaves if fab is not None else 1,
            leaf_recovery=recov_l[k])
    if pairs:
        return [cell(k, k, (k,)) for k in range(len(traces))]
    return [[cell(i, j, (i, j)) for j in range(len(configs))]
            for i in range(len(traces))]


def simulate_grid(traces: Sequence[Trace], configs: Sequence[PCSConfig], *,
                  max_pbe: int | None = None,
                  bucket: int = _BUCKET,
                  track_addrs: int = 0,
                  macro: bool = True) -> List[List[SimResult]]:
    """Simulate every (trace, config) cell in one compiled program.

    Returns a ``len(traces) x len(configs)`` nested list of SimResult.
    Schemes may be mixed freely; ``pm_banks`` must agree (array shape).
    ``bucket`` controls shape-padding granularity only — results are
    invariant to it.  A config's ``crash_at_ns`` is just another stacked
    traced scalar, so crash-point sweeps share the one program.
    ``track_addrs > 0`` additionally returns, per cell, the durable
    version vector over addresses ``[0, track_addrs)`` (the differential
    harness input); it is a static array shape, so changing it recompiles.
    A config's ``n_tenants`` is a traced scalar too — a {workload x
    scheme x tenant-count} sweep shares the program; only the *max*
    tenant count (per-tenant stats rows) is a static shape.
    ``macro`` (static) toggles the guarded macro-step fast path —
    results are bit-identical either way (the crash differential pins
    this); it exists so the tests can diff the two columns.
    """
    if not traces or not configs:
        return [[] for _ in traces]
    ops, addrs, gaps, lengths, mlen, n_steps = _stack_traces(traces, bucket)
    # static per-tenant stats row count; every config's rows beyond its
    # own n_tenants stay zero, so mixed tenant counts share one program
    n_tenants_max = max(c.n_tenants for c in configs)
    sc_np, schemes, max_pbe, pm_banks, n_deep, n_leaves = _stack_configs(
        configs, max_pbe, n_tenants_max)
    single = len(traces) == 1 and len(configs) == 1
    with enable_x64(), warnings.catch_warnings():
        # donated buffers the program cannot alias (dtype/layout) emit a
        # UserWarning; donation is best-effort here
        warnings.filterwarnings("ignore", message=".*[Dd]onat")
        if single:
            # 1x1 grid: skip the vmap so the op/scheme switches keep
            # their branch semantics (~4x less work per scan step)
            sc = {k: jnp.asarray(v[0], jnp.float64)
                  for k, v in sc_np.items()}
            out = _run_cell(
                jnp.asarray(ops[0]), jnp.asarray(addrs[0]),
                jnp.asarray(gaps[0]), jnp.asarray(lengths[0]),
                jnp.asarray(mlen[0]), jnp.asarray(schemes[0]), sc,
                max_pbe=max_pbe, n_steps=n_steps, pm_banks=pm_banks,
                n_track=track_addrs, n_tenants_max=n_tenants_max,
                n_deep_max=n_deep, n_leaves_max=n_leaves, macro=macro)
            out = tuple(np.asarray(o)[None, None] for o in out)
        else:
            sc = {k: jnp.asarray(v, jnp.float64) for k, v in sc_np.items()}
            out = _run_grid(
                jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(gaps),
                jnp.asarray(lengths), jnp.asarray(mlen),
                jnp.asarray(schemes), sc,
                max_pbe=max_pbe, n_steps=n_steps, pm_banks=pm_banks,
                n_track=track_addrs, n_tenants_max=n_tenants_max,
                n_deep_max=n_deep, n_leaves_max=n_leaves, macro=macro)
            out = tuple(np.asarray(o) for o in out)
    return _results_from(out, traces, configs, track_addrs, pairs=False)


def simulate_cells(traces: Sequence[Trace], configs: Sequence[PCSConfig], *,
                   max_pbe: int | None = None,
                   bucket: int = _BUCKET,
                   track_addrs: int = 0,
                   macro: bool = True) -> List[SimResult]:
    """Simulate paired cells: ``result[k]`` is (traces[k], configs[k]).

    The flat twin of :func:`simulate_grid` for sweeps that are not a
    cross product — e.g. a crash sweep anchored on two traces runs
    ``len(configs)`` cells instead of ``2 x len(configs)``.  One vmap
    axis, one compiled program; repeated Trace objects stack by
    reference on the host, so passing the same trace many times costs
    one pad, not many.
    """
    if not traces:
        return []
    if len(traces) != len(configs):
        raise ValueError("simulate_cells wants len(traces) == len(configs)")
    # stack unique traces once, then index the stacked arrays per pair
    uniq: List[Trace] = []
    index = {}
    for t in traces:
        if id(t) not in index:
            index[id(t)] = len(uniq)
            uniq.append(t)
    ops, addrs, gaps, lengths, mlen, n_steps = _stack_traces(uniq, bucket)
    sel = np.asarray([index[id(t)] for t in traces], np.int32)
    ops, addrs, gaps = ops[sel], addrs[sel], gaps[sel]
    lengths, mlen = lengths[sel], mlen[sel]
    n_tenants_max = max(c.n_tenants for c in configs)
    sc_np, schemes, max_pbe, pm_banks, n_deep, n_leaves = _stack_configs(
        configs, max_pbe, n_tenants_max)
    with enable_x64(), warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=".*[Dd]onat")
        sc = {k: jnp.asarray(v, jnp.float64) for k, v in sc_np.items()}
        out = _run_cells(
            jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(gaps),
            jnp.asarray(lengths), jnp.asarray(mlen),
            jnp.asarray(schemes), sc,
            max_pbe=max_pbe, n_steps=n_steps, pm_banks=pm_banks,
            n_track=track_addrs, n_tenants_max=n_tenants_max,
            n_deep_max=n_deep, n_leaves_max=n_leaves, macro=macro)
        out = tuple(np.asarray(o) for o in out)
    return _results_from(out, traces, configs, track_addrs, pairs=True)


def simulate(trace: Trace, config: PCSConfig,
             max_pbe: int | None = None, *,
             bucket: int = _BUCKET, track_addrs: int = 0,
             macro: bool = True) -> SimResult:
    """Simulate one (trace, config) pair and return aggregate metrics."""
    max_pbe = max_pbe or config.max_hop_pbe
    return simulate_grid([trace], [config], max_pbe=max_pbe,
                         bucket=bucket, track_addrs=track_addrs,
                         macro=macro)[0][0]


def simulate_sweep(trace: Trace, configs: List[PCSConfig], *,
                   bucket: int = _BUCKET) -> List[SimResult]:
    """vmap one trace over many configs (Fig. 1 / Fig. 8).

    All latency scalars *and the scheme id* are batched; the padded PBE
    capacity is the only shared static, so the whole sweep — including
    mixed-scheme sweeps — is a single compiled program.
    """
    if not configs:
        return []
    return simulate_grid([trace], configs, bucket=bucket)[0]
