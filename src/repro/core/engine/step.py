"""Step driver: merge per-core op streams by clock and run the scan.

One scan step = one trace op of the globally earliest unblocked core
(fence semantics: a core blocks on its persists and PM reads, so its
clock only advances when its op completes).  Padded steps after stream
exhaustion are provable no-ops, which lets callers pad the scan length
to shared buckets without changing any result.

``scan_cell`` is the unjitted single-cell program; the front-ends in
``engine.grid`` wrap it in ``jax.jit`` (single cell) or
``jit(vmap(vmap(...)))`` (full trace x config grid).  A module-level
compile counter increments once per trace of ``scan_cell`` — i.e. once
per XLA program built — backing the one-compilation acceptance test and
the BENCH_engine.json perf tracking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.handlers import HANDLERS, StepCtx
from repro.core.engine.state import INF, MachineState, init_state
from repro.core.params import Op

# Incremented inside `scan_cell` at trace time: one tick per XLA program
# built from the engine (jit caches hits do not retrace).
_COMPILES = [0]


def compile_count() -> int:
    """Number of engine XLA programs traced/compiled so far this process."""
    return _COMPILES[0]


def scan_cell(ops, addrs, gaps, lengths, scheme, sc, *,
              max_pbe: int, n_steps: int, pm_banks: int):
    """Simulate one (trace, config) cell; returns (runtime, stats).

    ``scheme`` and every entry of ``sc`` are traced scalars; only array
    shapes (core count C, ``max_pbe``, ``pm_banks``, ``n_steps``) are
    static.
    """
    _COMPILES[0] += 1
    C = ops.shape[0]
    slot_ids = jnp.arange(max_pbe)
    slot_active = slot_ids < sc["n_pbe"].astype(jnp.int32)
    # Cores with a non-empty stream participate in barriers (padded cores
    # from stacked grids have zero-length streams and never arrive).
    n_live = jnp.sum((lengths > 0).astype(jnp.int32))

    def step(st: MachineState, _):
        active = st.ptr < lengths
        # blocked cores wait at a barrier and cannot be selected
        tsel = jnp.where(active & ~st.blocked, st.clock, INF)
        c = jnp.argmin(tsel)
        # padded steps after exhaustion (or a barrier mismatch) are no-ops
        valid = jnp.any(active) & (tsel[c] < INF * 0.5)
        i = jnp.minimum(st.ptr[c], lengths[c] - 1)
        op = jnp.where(valid, ops[c, i], int(Op.COMPUTE))
        addr = addrs[c, i]
        gap = jnp.where(valid, gaps[c, i].astype(jnp.float64), 0.0)
        t = jnp.where(valid, tsel[c], st.clock[c]) + gap

        ctx = StepCtx(c=c, t=t, addr=addr, scheme=scheme, sc=sc,
                      slot_ids=slot_ids, slot_active=slot_active,
                      n_live=n_live, n_banks=pm_banks)
        branches = [lambda s, h=h: h(ctx, s) for h in HANDLERS]
        st2 = jax.lax.switch(jnp.clip(op, 0, 5), branches, st)

        is_bar = valid & (op == int(Op.BARRIER))
        last = is_bar & ((st.bcount + 1) >= n_live)
        blocked = jnp.where(last, jnp.zeros_like(st.blocked),
                            jnp.where(is_bar, st.blocked.at[c].set(True),
                                      st.blocked))
        bcount = jnp.where(last, 0,
                           jnp.where(is_bar, st.bcount + 1, st.bcount))
        ptr = st2.ptr.at[c].add(jnp.where(valid, 1, 0))
        return st2._replace(ptr=ptr, blocked=blocked, bcount=bcount), None

    final, _ = jax.lax.scan(step, init_state(C, max_pbe, pm_banks), None,
                            length=n_steps)
    runtime = jnp.max(jnp.where(final.clock < INF * 0.5, final.clock, 0.0))
    return runtime, final.stats
