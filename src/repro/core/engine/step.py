"""Step driver: merge per-core op streams by issue time and run the scan.

One scan step = one trace op of the core whose next op *issues*
earliest (core clock + compute gap; fence semantics: a core blocks on
its persists and PM reads, so its clock only advances when its op
completes).  Merging on issue time rather than bare clocks makes the
global op order well-defined even under wildly heterogeneous gaps —
the property the crash model and the differential conformance harness
(tests/_crash_driver.py) rest on.  Padded steps after stream
exhaustion are provable no-ops, which lets callers pad the scan length
to shared buckets without changing any result.

Two step-count optimizations ride on that no-op property:

  * **macro-stepping** (``engine.macro``): when the trace-time run plan
    (``mlen``) marks an eligible homogeneous window at the selected
    core's cursor, the step executes up to ``MACRO_KMAX`` ops at once
    behind a traced guard conjunction, falling back to the
    slot-at-a-time handlers on guard failure — bit-exact either way;
  * **chunked early exit**: the scan runs in ``CHUNK``-step segments
    under a ``while_loop`` that stops at the first segment boundary
    where every core has drained its stream, so bucket-padded
    ``n_steps`` costs nothing once the real work (shortened further by
    macro-steps) is done.  Exactly ``n_steps`` steps are executed in
    the worst case — never more — so short-scan callers see the old
    semantics unchanged.

Crash semantics (Section V-D4): ``sc["crash_at"]`` is a traced scalar;
an op whose issue time exceeds it becomes a no-op (the machine is off),
and after the scan a recovery pass (``handlers.recovery_snapshot``)
computes the durable-version vector and the drain-all cost over the
surviving Dirty/Drain PBEs.

``scan_cell`` is the unjitted single-cell program; the front-ends in
``engine.grid`` wrap it in ``jax.jit`` (single cell) or
``jit(vmap(vmap(...)))`` (full trace x config grid).  A module-level
compile counter increments once per trace of ``scan_cell`` — i.e. once
per XLA program built — backing the one-compilation acceptance test and
the BENCH_engine.json perf tracking.  ``return_state=True`` traces
(the padding-invariant tests' state-introspection path) are excluded
from the counter: they are test-only retraces of an already-counted
program shape, and counting them double-billed suites that mix both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.handlers import HANDLERS, StepCtx, recovery_snapshot
from repro.core.engine.macro import MACRO_ABORT_REASONS, macro_step
from repro.core.engine.state import (EPOCH_KEYS, INF, MachineState,
                                     init_state)
from repro.core.params import MACRO_KMAX, Op

# Incremented inside `scan_cell` at trace time: one tick per XLA program
# built from the engine (jit cache hits do not retrace; test-only
# return_state traces are excluded, see module docstring).
_COMPILES = [0]

# Steps per inner scan segment of the chunked driver.  Segment
# boundaries only ever skip provable no-op steps (every core past its
# stream end), so results are invariant to this constant; it trades
# while_loop trip overhead against wasted post-exhaustion steps.
CHUNK = 128


def compile_count() -> int:
    """Number of engine XLA programs traced/compiled so far this process."""
    return _COMPILES[0]


def resolve_epoch_sc(sc, t_issue):
    """Select the active epoch's operand rows at an op's issue time.

    Grids carrying a schedule axis stack the :data:`EPOCH_KEYS` rows of
    ``sc`` with a leading ``(E,)`` epoch dimension plus one shared
    ``(E - 1,)`` ``epoch_bounds`` vector (``state.scalars_from_config``).
    The active epoch is ``#{b : b <= t_issue}`` — the boundary instant
    belongs to the *new* epoch, mirroring the crash gate's
    ``t_issue <= crash_at`` convention — and unused boundary slots are
    padded with ``INF``, which can never be ``<=`` a finite issue time.

    Returns ``(sc_op, next_bound)``: an sc view whose scheduled keys are
    indexed down to the old per-epoch shapes (so the handlers, policy,
    chain, fabric and macro layers consume them verbatim), and the next
    boundary strictly after ``t_issue`` (``INF`` in the last epoch) for
    the macro window's epoch-consistency guard.  The branch is decided
    Python-statically on dict membership: single-epoch grids lower the
    flat dict and return it unchanged with ``next_bound=None``, keeping
    their XLA program byte-identical to a schedule-free engine.
    """
    if "epoch_bounds" not in sc:
        return sc, None
    eb = sc["epoch_bounds"]
    ep = jnp.sum((eb <= t_issue).astype(jnp.int32))
    sc_op = {k: (v[ep] if k in EPOCH_KEYS else v)
             for k, v in sc.items() if k != "epoch_bounds"}
    next_bound = jnp.min(jnp.where(eb > t_issue, eb, INF))
    return sc_op, next_bound


def scan_cell(ops, addrs, gaps, lengths, scheme, sc, *,
              max_pbe: int, n_steps: int, pm_banks: int, n_track: int = 0,
              n_tenants_max: int = 1, n_deep_max: int = 0,
              n_leaves_max: int = 1,
              mlen=None, macro: bool = False,
              return_state: bool = False):
    """Simulate one (trace, config) cell.

    Returns ``(runtime, stats, durable_ver, n_recovered, recovery_ns,
    recovered_per_tenant, hop_stats, recovered_per_hop,
    recovered_per_leaf, macro_ops, macro_aborts)``, plus the final
    :class:`MachineState` when ``return_state`` is set (used by the
    padding-invariant tests).  ``scheme`` and every entry of ``sc`` are
    traced scalars; only array shapes (core count C, ``max_pbe``,
    ``pm_banks``, ``n_steps``, ``n_track``, ``n_tenants_max``,
    ``n_deep_max``, ``n_leaves_max``) are static.  ``n_deep_max`` is
    the deep-hop row count of the switch chain (grid max depth minus
    one); 0 skips the chain code entirely at trace time, so depth-1
    grids stay byte-identical to the pre-chain engine.  ``n_leaves_max``
    plays the same role for the fan-out fabric axis (``engine.fabric``):
    1 keeps the per-leaf PBC column empty and skips every fabric branch
    at trace time; ``recovered_per_leaf`` then degenerates to a single
    aggregate cell.  ``macro_aborts`` is the per-reason count of live
    macro candidates that failed to commit
    (:data:`~repro.core.engine.macro.MACRO_ABORT_REASONS` order, all
    zero when ``macro`` is off).

    ``macro=True`` (static) enables the macro-stepping fast path;
    ``mlen`` is the (C, L) int8 run plan from
    ``core.traces.plan_runs``.  The caller must then pad the trace
    axis L by at least ``MACRO_KMAX`` slots past the longest stream
    (the grid stacker does) so the window slice never clamps.
    ``macro_ops`` counts the trace slots executed via macro-steps
    (0 when disabled) — the ``macro_hit_rate`` numerator.

    Tenancy: ``sc["n_tenants"]`` (traced) partitions the *live* cores
    into contiguous balanced groups — core ``c`` belongs to tenant
    ``floor(c * T / n_live)`` — that share the PB slots, the PBC FIFO
    and the PM banks but keep independent barriers and stats rows
    (``core.traces.tenant_ids`` is the numpy twin of this mapping).
    """
    if not return_state:
        _COMPILES[0] += 1
    use_macro = bool(macro) and mlen is not None
    C = ops.shape[0]
    slot_ids = jnp.arange(max_pbe)
    slot_active = slot_ids < sc["n_pbe"].astype(jnp.int32)
    # Cores with a non-empty stream participate in barriers (padded cores
    # from stacked grids have zero-length streams and never arrive).
    n_live = jnp.sum((lengths > 0).astype(jnp.int32))
    core_ids = jnp.arange(C)
    # Per-core tenant ids: balanced contiguous partition of the live
    # cores; padded cores get a clipped id but never issue ops, never
    # arrive at barriers and never touch a stats row.
    t_int = jnp.maximum(sc["n_tenants"].astype(jnp.int32), 1)
    tids = jnp.clip((core_ids * t_int) // jnp.maximum(n_live, 1), 0,
                    jnp.minimum(t_int, n_tenants_max) - 1)
    live_per_tenant = jnp.zeros((n_tenants_max,), jnp.int32).at[tids].add(
        (lengths > 0).astype(jnp.int32))
    # per-step invariant: the issue-time merge runs in f64, so widen the
    # stored f32 gaps once instead of on every step
    gaps64 = gaps.astype(jnp.float64)

    def step(carry, _):
        st, mops, maborts = carry
        active = st.ptr < lengths
        idx = jnp.minimum(st.ptr, jnp.maximum(lengths - 1, 0))
        next_gap = gaps64[core_ids, idx]
        # blocked cores wait at a barrier and cannot be selected; all
        # others compete on the *issue* time of their next op
        tsel = jnp.where(active & ~st.blocked, st.clock + next_gap, INF)
        c = jnp.argmin(tsel)
        # padded steps after exhaustion (or a barrier mismatch) are no-ops
        valid = jnp.any(active) & (tsel[c] < INF * 0.5)
        i = idx[c]
        t_issue = jnp.where(valid, tsel[c], st.clock[c])
        # ops issuing after the power loss never happen (machine is off)
        live = valid & (t_issue <= sc["crash_at"])
        op = jnp.where(live, ops[c, i], int(Op.COMPUTE))
        t = jnp.where(live, t_issue, st.clock[c])
        # epoched schedules: every layer below sees the operand rows of
        # the epoch active at this op's *issue* time
        sc_op, next_bound = resolve_epoch_sc(sc, t_issue)

        tid_c = tids[c]
        n_live_t = live_per_tenant[tid_c]
        ctx = StepCtx(c=c, t=t, addr=addrs[c, i], scheme=scheme, sc=sc_op,
                      slot_ids=slot_ids, slot_active=slot_active,
                      tenant=tid_c, tids=tids, n_live_t=n_live_t,
                      n_banks=pm_banks, n_track=n_track)
        branches = [lambda s, h=h: h(ctx, s) for h in HANDLERS]
        st2 = jax.lax.switch(jnp.clip(op, 0, 5), branches, st)

        if use_macro:
            st_m, took, k_m, ab_vec = macro_step(
                ctx, st, ops, addrs, gaps64, lengths, mlen, tsel,
                valid, live, t_issue, i, kmax=MACRO_KMAX,
                next_epoch_bound=next_bound)
            st2 = jax.tree_util.tree_map(
                lambda a, b: jnp.where(took, a, b), st_m, st2)
            adv = jnp.where(took, k_m, 1)
            mops = mops + jnp.where(took, k_m, 0)
            maborts = maborts + ab_vec
        else:
            took = jnp.asarray(False)
            adv = 1

        # barriers synchronize only within a tenant (independent hosts);
        # macro windows contain no barriers, so the bookkeeping below is
        # an exact identity whenever the macro path was taken
        is_bar = live & (op == int(Op.BARRIER))
        last = is_bar & ((st.bcount[tid_c] + 1) >= n_live_t)
        blocked = jnp.where(last & (tids == tid_c), False,
                            jnp.where(is_bar, st.blocked.at[c].set(True),
                                      st.blocked))
        bcount = st.bcount.at[tid_c].set(
            jnp.where(last, 0,
                      st.bcount[tid_c] + jnp.where(is_bar, 1, 0)))
        # crashed ops still consume their cursor slot (the stream drains
        # as no-ops, so post-crash cores cannot starve live ones) and
        # still advance the core clock to their issue time: gaps are
        # relative, so a frozen clock would let a *later* op's issue
        # time collapse back below the crash point and wrongly execute
        # (a dead-run macro-step already advanced the clock itself)
        ptr = st2.ptr.at[c].add(jnp.where(valid, adv, 0))
        clock = st2.clock.at[c].set(
            jnp.where(valid & ~live & ~took, t_issue, st2.clock[c]))
        return (st2._replace(clock=clock, ptr=ptr, blocked=blocked,
                             bcount=bcount), mops, maborts), None

    def segment(carry, length):
        return jax.lax.scan(step, carry, None, length=length)[0]

    carry = (init_state(C, max_pbe, pm_banks, n_track, n_tenants_max,
                        n_deep_max, n_leaves_max),
             jnp.zeros((), jnp.int32),
             jnp.zeros((len(MACRO_ABORT_REASONS),), jnp.int32))
    n_full, n_tail = divmod(n_steps, CHUNK)
    if n_full > 0:
        def more_work(loop):
            k, (st, _mops, _mab) = loop
            return (k < n_full) & jnp.any(st.ptr < lengths)

        def run_segment(loop):
            k, seg_carry = loop
            return k + 1, segment(seg_carry, CHUNK)

        _, carry = jax.lax.while_loop(
            more_work, run_segment, (jnp.asarray(0, jnp.int32), carry))
    if n_tail > 0:
        carry = segment(carry, n_tail)
    final, mops, maborts = carry
    # a crashed run ends at the power loss: dead cores advanced their
    # clocks through never-executed ops, so cap at the crash instant
    runtime = jnp.max(jnp.where(final.clock < INF * 0.5,
                                jnp.minimum(final.clock, sc["crash_at"]),
                                0.0))
    (durable_ver, n_recov, recov_ns, recov_t, recov_h,
     recov_l) = recovery_snapshot(
        final, scheme, sc, slot_active, pm_banks, n_track)
    out = (runtime, final.stats, durable_ver, n_recov, recov_ns, recov_t,
           final.hop_stats, recov_h, recov_l, mops, maborts)
    return out + (final,) if return_state else out
