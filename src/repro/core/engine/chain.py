"""Switch-chain forwarding: per-switch persistent buffers (DESIGN.md §5).

The pooling topology promotes ``n_switches`` from a latency multiplier
into a simulated chain: hop 1 (the tenant-facing ack point) keeps the
flat legacy PB columns of :class:`~repro.core.engine.state.MachineState`,
and every deeper switch owns one row of the ``(D, P)`` deep-hop columns.
A hop-1 drain no longer writes PM directly — it travels one inter-switch
segment to hop 2's PBC, commits into hop 2's persistent cells (the ack
that frees the hop-1 entry returns from there), and later propagates
further down per the scheme's drain policy:

  * **PB** (drain-immediate): every hop forwards what it just committed
    — a store-and-forward pipeline whose entries transit in Drain;
  * **PB_RF**: every hop retains Dirty entries and runs its *own*
    threshold/preset drain-down (per-hop counts lowered as traced
    vectors, ``params.hop_drain_counts``), coalescing arrivals into an
    existing Dirty entry for the same line.

An arrival that finds a hop full (no coalesce, no Empty slot after
lazy-free) **bypasses** the hop and continues toward PM — capacity
pressure degrades the chain to write-through instead of deadlocking on
recursive victim cascades.  Packets that run out of switches land at PM
with the per-bank burst serialization of the legacy drain path.

Crash semantics: a packet whose downstream commit lands after
``crash_at`` dies on the wire — the target hop's table is untouched and
the origin entry survives in Drain (its ack time is past the crash), so
an acked persist is always recoverable from the deepest hop it reached
(the union rule of ``handlers.recovery_snapshot``).

Everything here is traced: the chain depth, per-hop capacities and
drain counts are scalars/vectors of ``sc``, so a mixed {workload x
scheme x depth x policy} sweep stays ONE XLA program.  Only the
grid-wide maximum depth (``D = n_deep_max``, a static array shape) is
compile-time; when every config in a grid is depth <= 1, ``D == 0`` and
the whole module is skipped at trace time — depth-1 programs are
byte-identical to the pre-chain engine.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.engine import channels
from repro.core.engine.state import (DIRTY, DRAIN, EMPTY, INF, H_BYPASS,
                                     H_COALESCES, H_FWD_CNT, H_FWD_SUM,
                                     MachineState)

F = jnp.float64


class Batch(NamedTuple):
    """Packets in flight between two adjacent switches (wire order)."""

    active: jnp.ndarray  # (Q,) bool
    addr: jnp.ndarray    # (Q,) i32
    ver: jnp.ndarray     # (Q,) i32
    owner: jnp.ndarray   # (Q,) i8 (the packed MachineState owner dtype;
                         #          `_place`'s injective pick() sums carry
                         #          it through without widening)
    emit: jnp.ndarray    # (Q,) f64  emission time at the previous switch
    ohop: jnp.ndarray    # (Q,) i32  origin hop (0 = hop-1 flat columns,
                         #           m > 0 = deep row m-1) for dd writeback
    oslot: jnp.ndarray   # (Q,) i32  origin PBE slot


def _last_writer(mask, oslot):
    """Keep only the last packet (batch order) targeting each origin slot.

    One cascade can emit two packets from the same hop-1 slot (the
    victim's old entry, then the reused slot's new entry drained by the
    drain-down); the slot's dd must be the later packet's ack.
    """
    q = jnp.arange(mask.shape[0])
    later = (oslot[None, :] == oslot[:, None]) & (q[None, :] > q[:, None]) \
        & mask[None, :]
    return mask & ~jnp.any(later, axis=1)


def _scatter_dd(dd1, ddd, batch: Batch, vals, mask):
    """Write per-packet ack times back to the origin entries' dd."""
    D = ddd.shape[0]
    m0 = _last_writer(mask & (batch.ohop == 0), batch.oslot)
    dd1 = dd1.at[batch.oslot].set(jnp.where(m0, vals, dd1[batch.oslot]))
    for m in range(1, D + 1):
        mm = _last_writer(mask & (batch.ohop == m), batch.oslot)
        ddd = ddd.at[m - 1, batch.oslot].set(
            jnp.where(mm, vals, ddd[m - 1, batch.oslot]))
    return dd1, ddd


def _pm_land(sc, pos, batch: Batch, pm_busy, pm_ver, n_banks, n_track):
    """Packets at switch ``pos`` with no deeper switch write through to PM.

    Same per-bank burst serialization as the legacy drain path; the ack
    returns up the chain to the origin switch.  Returns
    ``(pm_busy, pm_ver, dd_vals (Q,), n_writes)``.
    """
    crash = sc["crash_at"]
    A = pm_ver.shape[0]
    act = batch.active
    # remaining wire: switch pos -> PM through the switches below it
    rem = jnp.maximum(sc["n_switches"] - float(pos), 0.0)
    path_down = sc["link_ns"] + rem * sc["hop_ns"]
    arr = batch.emit + path_down
    bank = batch.addr % n_banks
    same_bank = bank[None, :] == bank[:, None]
    q = jnp.arange(act.shape[0])
    earlier = q[None, :] < q[:, None]
    rank_b = jnp.sum((same_bank & earlier & act[None, :]).astype(F), axis=1)
    start = jnp.maximum(pm_busy[bank], arr) + rank_b * sc["nvm_w_occ"]
    # ack back at the origin switch o: PM -> switch n -> ... -> switch o
    o = batch.ohop + 1
    path_up = sc["link_ns"] + jnp.maximum(
        sc["n_switches"] - o.astype(F), 0.0) * sc["hop_ns"]
    dd_vals = start + sc["nvm_write"] + path_up
    busy_after = jnp.where(same_bank & act[None, :],
                           (start + sc["nvm_w_occ"])[None, :], 0.0).max(axis=1)
    pm_busy2 = jnp.maximum(
        pm_busy, jnp.zeros_like(pm_busy).at[bank].max(
            jnp.where(act, busy_after, 0.0)))
    ok = act & (dd_vals <= crash) & (batch.addr >= 0) \
        & (batch.addr < n_track)
    pm_ver2 = pm_ver.at[jnp.clip(batch.addr, 0, A - 1)].max(
        jnp.where(ok, batch.ver, 0))
    return pm_busy2, pm_ver2, dd_vals, jnp.sum(act.astype(F))


def _place(sc, j, scheme, rows, hpbc_j, batch: Batch, hop_stats):
    """Commit a batch into deep row ``j`` (switch j+2) and run its drain.

    ``rows`` holds the current (D, P) deep columns.  Returns ``(row
    updates dict, hpbc_j, hop_stats, dd_vals, ended, next Batch)``.  All
    packet addresses in a batch are distinct (each hop holds at most one
    Dirty entry per line), so coalesce matching is injective and Empty
    slots are assigned by rank without sequential scanning.  Placement
    mutations are gated on ``commit <= crash_at`` — a packet that
    commits after the power loss dies on the wire and must not clobber a
    surviving entry.
    """
    crash = sc["crash_at"]
    P = rows["dtag"].shape[1]
    slot_ids = jnp.arange(P, dtype=jnp.int32)
    slot_act = slot_ids < sc["deep_pbe"][j].astype(jnp.int32)
    act = batch.active
    any_act = jnp.any(act)

    arr = batch.emit + sc["hop_ns"]
    starts, hpbc_j = channels.fifo_service(hpbc_j, arr, act,
                                           sc["pbc_occ_ns"])
    classify = starts + sc["pbc_proc_ns"] + sc["deep_tag"][j]
    commit = classify + sc["deep_data"][j]

    # lazy-free observed once at the batch head (single settle point)
    t0 = jnp.where(any_act, jnp.min(jnp.where(act, classify, INF)), -INF)
    freed = (rows["dstate"][j] == DRAIN) & (rows["ddd"][j] <= t0)
    state0 = jnp.where(freed, EMPTY, rows["dstate"][j])

    co = act[:, None] & slot_act[None, :] \
        & (batch.addr[:, None] == rows["dtag"][j][None, :]) \
        & (state0 == DIRTY)[None, :]
    has_co = jnp.any(co, axis=1)
    alloc = act & ~has_co
    empty = slot_act & (state0 == EMPTY)
    erank = jnp.cumsum(empty.astype(jnp.int32)) - 1
    arank = jnp.cumsum(alloc.astype(jnp.int32)) - 1
    placed = alloc & (arank < jnp.sum(empty.astype(jnp.int32)))
    bypass = alloc & ~placed
    amat = placed[:, None] & empty[None, :] \
        & (arank[:, None] == erank[None, :])

    gate = commit <= crash
    mat = (co | amat) & gate[:, None]
    upd = jnp.any(mat, axis=0)

    def pick(v, zero):
        # injective scatter: at most one packet row per slot column
        return jnp.sum(jnp.where(mat, v[:, None], zero), axis=0,
                       dtype=v.dtype)

    al = jnp.any(amat & gate[:, None], axis=0)
    co_upd = jnp.any(co & gate[:, None], axis=0)
    tag1 = jnp.where(al, pick(batch.addr, 0), rows["dtag"][j])
    state1 = jnp.where(al, DIRTY, state0)
    # Fan-in version ordering: with several leaves feeding this hop,
    # drains for one line can arrive out of version order (leaf A's v5
    # lands before leaf B's v3) — a coalesce keeps the *newest* of the
    # arriving and resident versions, and the owner follows whichever
    # version wins.  On a linear chain the per-hop per-line version
    # stream is monotone, so max(arriving, resident) == arriving and
    # this is bit-identical to the pre-fabric overwrite.
    ver_in = pick(batch.ver, 0)
    ver1 = jnp.where(al, ver_in,
                     jnp.where(co_upd,
                               jnp.maximum(ver_in, rows["dver"][j]),
                               rows["dver"][j]))
    keep_owner = co_upd & (rows["dver"][j] > ver_in)
    owner1 = jnp.where(upd & ~keep_owner, pick(batch.owner, 0),
                       rows["downer"][j])
    t_new = pick(commit, 0.0)
    lru1 = jnp.where(upd, t_new, rows["dlru"][j])
    wt1 = jnp.where(upd, t_new, rows["dwt"][j])

    ended = has_co | placed            # packets that stop at this hop
    hop_stats = hop_stats.at[j + 1, H_FWD_CNT].add(
        jnp.sum((ended & gate).astype(F)))
    hop_stats = hop_stats.at[j + 1, H_FWD_SUM].add(
        jnp.sum(jnp.where(ended & gate, commit - batch.emit, 0.0)))
    hop_stats = hop_stats.at[j + 1, H_COALESCES].add(
        jnp.sum((has_co & gate).astype(F)))
    hop_stats = hop_stats.at[j + 1, H_BYPASS].add(
        jnp.sum((bypass & gate).astype(F)))

    # dd writeback: every committed packet acks its origin entry, gated
    # or not (a post-crash commit still yields a post-crash ack time —
    # exactly what keeps the origin entry alive through the crash)
    dd_vals = commit + (float(j + 2) - (batch.ohop.astype(F) + 1.0)) \
        * sc["hop_ns"]

    # this hop's own drain-down (evaluated once, after the batch settles)
    dirty = slot_act & (state1 == DIRTY)
    dirty_cnt = jnp.sum(dirty.astype(F))
    k_rf = jnp.where(dirty_cnt >= sc["deep_thr"][j],
                     dirty_cnt - sc["deep_pre"][j], 0.0)
    k = jnp.where(scheme == 1, dirty_cnt, k_rf)     # PB forwards everything
    key = jnp.where(dirty, lru1, INF)
    rank = jnp.argsort(jnp.argsort(key)).astype(F)
    to_drain = (rank < k) & dirty
    t_row = jnp.maximum(
        jnp.max(jnp.where(ended & gate, commit, -INF)), 0.0)
    state2 = jnp.where(to_drain, DRAIN, state1)

    # the drain-down set leaves in LRU order (the wire order the oracle
    # replays; downstream LRU stamps — and who bypasses a full hop —
    # depend on it)
    order = jnp.argsort(key).astype(jnp.int32)
    nxt = Batch(
        active=jnp.concatenate([bypass, to_drain[order]]),
        addr=jnp.concatenate([batch.addr, tag1[order]]),
        ver=jnp.concatenate([batch.ver, ver1[order]]),
        owner=jnp.concatenate([batch.owner, owner1[order]]),
        emit=jnp.concatenate([jnp.where(bypass, classify, 0.0),
                              jnp.zeros((P,), F) + t_row]),
        ohop=jnp.concatenate([batch.ohop,
                              jnp.full((P,), j + 1, jnp.int32)]),
        oslot=jnp.concatenate([batch.oslot, order]),
    )
    row = dict(dtag=tag1, dstate=state2, dlru=lru1, dver=ver1,
               downer=owner1, dwt=wt1)
    return row, hpbc_j, hop_stats, dd_vals, ended, nxt


def rows_of(st: MachineState) -> dict:
    """The deep-hop columns of the machine state as a mutable dict."""
    return dict(dtag=st.dtag, dstate=st.dstate, dlru=st.dlru, ddd=st.ddd,
                dver=st.dver, downer=st.downer, dwt=st.dwt)


def forward_chain(sc, scheme, rows, hpbc, hop_stats, batch: Batch, dd1,
                  pm_busy, pm_ver, *, n_banks: int, n_track: int):
    """Propagate a hop-1 drain batch down the whole chain.

    ``dd1`` is the hop-1 dd column the origin acks scatter into; ``rows``
    (see :func:`rows_of`) the deep columns the cascade threads through.
    Returns ``(dd1, rows, hpbc, hop_stats, pm_busy, pm_ver,
    n_pm_writes)``.  The loop is unrolled over the static deep row
    count; each iteration either commits the batch into its row
    (``row_live``, the traced depth covers it) or lands every packet at
    PM — selected per cell, so mixed depths share the program.
    """
    D = rows["dtag"].shape[0]
    rows = dict(rows)
    pm_writes = jnp.asarray(0.0, F)
    for j in range(D):
        row_live = (float(j) + 2.0) <= sc["n_switches"]
        row, hpbc_j, hs_place, ddv_p, ended, nxt = _place(
            sc, j, scheme, rows, hpbc[j], batch, hop_stats)
        pmb_l, pmv_l, ddv_l, n_l = _pm_land(
            sc, j + 1, batch, pm_busy, pm_ver, n_banks, n_track)
        # select: commit into the row vs write through to PM
        for kf, v in row.items():
            rows[kf] = rows[kf].at[j].set(
                jnp.where(row_live, v, rows[kf][j]))
        hpbc = hpbc.at[j].set(jnp.where(row_live, hpbc_j, hpbc[j]))
        hop_stats = jnp.where(row_live, hs_place, hop_stats)
        pm_busy = jnp.where(row_live, pm_busy, pmb_l)
        pm_ver = jnp.where(row_live, pm_ver, pmv_l)
        pm_writes = pm_writes + jnp.where(row_live, 0.0, n_l)
        dd_vals = jnp.where(row_live, ddv_p, ddv_l)
        dd_mask = batch.active & jnp.where(row_live, ended, True)
        dd1, rows["ddd"] = _scatter_dd(dd1, rows["ddd"], batch, dd_vals,
                                       dd_mask)
        batch = nxt._replace(active=jnp.where(row_live, nxt.active, False))
    # packets below the deepest allocated row write through to PM
    pmb_l, pmv_l, ddv_l, n_l = _pm_land(
        sc, D + 1, batch, pm_busy, pm_ver, n_banks, n_track)
    dd1, rows["ddd"] = _scatter_dd(dd1, rows["ddd"], batch, ddv_l,
                                   batch.active)
    return (dd1, rows, hpbc, hop_stats, pmb_l, pmv_l,
            pm_writes + n_l)


def deep_read(sc, st: MachineState, addr, t):
    """Read-forwarding checks below hop 1 (shallowest live entry wins).

    Returns ``(hit, resp, dlru', hop_row)`` — whether any deep hop can
    serve the read, the response time at the core, the LRU columns with
    the serving entry touched, and the serving row index (for the
    per-hop read-hit telemetry).  An entry is visible only once its
    commit time has passed (``dwt <= t``) and servable under the same
    Dirty-or-late-Drain rule as hop 1.
    """
    D = st.dtag.shape[0]
    P = st.dtag.shape[1]
    slot_ids = jnp.arange(P, dtype=jnp.int32)
    hit = jnp.zeros((D,), bool)
    resp = jnp.zeros((D,), F)
    idxs = jnp.zeros((D,), jnp.int32)
    for j in range(D):
        row_live = (float(j) + 2.0) <= sc["n_switches"]
        slot_act = slot_ids < sc["deep_pbe"][j].astype(jnp.int32)
        arr = t + sc["ow_cpu_sw1"] + (float(j) + 1.0) * sc["hop_ns"]
        live = slot_act & (st.dtag[j] == addr) \
            & (st.dstate[j] != EMPTY) & (st.dwt[j] <= t)
        served = live & ((st.dstate[j] == DIRTY)
                         | ((st.dstate[j] == DRAIN)
                            & (st.ddd[j] > arr + sc["fwd_margin"])))
        has = jnp.any(served) & row_live
        # a Dirty entry supersedes a late-Drain one (same rule as the
        # hop-1 pb_lookup: the Dirty copy is the newer version)
        sd = served & (st.dstate[j] == DIRTY)
        idx = jnp.where(jnp.any(sd), jnp.argmax(sd),
                        jnp.argmax(served)).astype(jnp.int32)
        hit = hit.at[j].set(has)
        idxs = idxs.at[j].set(idx)
        resp = resp.at[j].set(
            arr + sc["pbc_read_ns"] + sc["deep_tag"][j]
            + sc["deep_data"][j]
            + sc["ow_cpu_sw1"] + (float(j) + 1.0) * sc["hop_ns"])
    first = jnp.argmax(hit)                       # shallowest serving hop
    any_hit = jnp.any(hit)
    dlru = st.dlru
    for j in range(D):
        serve_j = any_hit & (first == j)
        dlru = dlru.at[j, idxs[j]].set(
            jnp.where(serve_j, t, dlru[j, idxs[j]]))
    return any_hit, resp[first], dlru, first
