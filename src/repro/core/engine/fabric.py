"""Fan-out fabric layer: leaf switches sharing one spine.

A :class:`~repro.core.params.FabricTopology` lowers onto the existing
chain machinery (``engine.chain``) rather than adding a second PB
implementation: the leaves *partition the hop-1 slot axis* (leaf ``i``
owns the contiguous slot window starting at ``sc["leaf_base"][i]``),
and the spine is simply deep-hop row 0 — its occupancy-serialized
``hpbc`` FIFO is exactly the fan-in contention point, because drains
from every leaf serialize through it.

Everything here is a pure mask/index helper over the traced operands
``n_leaves`` / ``leaf_of_t`` / ``leaf_base`` / ``bp_high``
(``state.scalars_from_config``), so a mixed {chain x fabric x
placement} grid stays ONE XLA program:

* ``slot_leaf`` maps each hop-1 slot to its owning leaf from the traced
  base vector (non-fabric configs lower ``leaf_base = [0, INF, ...]``,
  so every slot maps to leaf 0).
* ``leaf_mask`` scopes hop-1 lookup/alloc/victim/drain to the issuing
  tenant's leaf window; the ``n_leaves < 2`` bypass restores the global
  hop-1 behaviour bit-exactly for chain cells sharing the grid.
* ``spine_live`` is the spine PB's Dirty occupancy — the backpressure
  signal ``params.spine_defer`` compares against ``bp_high``.

The per-leaf PBC clocks live in ``MachineState.lpbc`` (shape ``(NL,)``
with NL = grid-wide ``n_leaves_max`` when > 1, else 0): each leaf is a
physically separate switch with its own PBC front, so their service
clocks must not serialize against each other.  ``NL == 0`` skips every
fabric branch at trace time — chain-only grids stay byte-identical to
the pre-fabric engine (the same trick the deep-hop axis plays with
``D == 0``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.state import DIRTY


def has_fabric(st) -> bool:
    """Python-static: does this *grid* carry the fabric axis at all?"""
    return st.lpbc.shape[0] > 0


def leaf_of_tenant(sc, tenant):
    """Traced leaf id of the issuing tenant (0 for non-fabric configs)."""
    return sc["leaf_of_t"][tenant].astype(jnp.int32)


def slot_leaf(sc, slot_ids):
    """Owning leaf of each hop-1 slot, from the traced base vector.

    ``leaf_base`` is cumulative capacity offsets padded with INF past
    the config's leaf count, so the count of bases at-or-below a slot
    id minus one is its leaf — and every slot of a non-fabric config
    (bases ``[0, INF, ...]``) lands on leaf 0.
    """
    nl = sc["leaf_base"].shape[0]
    below = slot_ids[:, None] >= sc["leaf_base"][None, :]
    lf = jnp.sum(below, axis=1).astype(jnp.int32) - 1
    return jnp.clip(lf, 0, nl - 1)


def leaf_mask(sc, sl, my_leaf):
    """Hop-1 slot mask scoping a tenant's PB operations to its leaf.

    ``sl`` is :func:`slot_leaf`'s output.  The ``n_leaves < 2`` bypass
    keeps chain cells (and 1-leaf fabrics) on the *global* hop-1
    behaviour bit-exactly inside a mixed grid — and keeps the traced
    ``n_leaves`` operand live so the retrace pass can see it.
    """
    return (sl == my_leaf) | (sc["n_leaves"] < 2.0)


def spine_live(sc, dstate_row, slot_ids):
    """Spine PB Dirty occupancy (entries) — the backpressure signal.

    Counts live Dirty entries inside the spine's real capacity
    (``deep_pbe[0]``; slots past it are structural padding).  Drain
    (in-flight to PM) entries have already left the spine's accept
    queue, so they do not push back on the leaves.
    """
    live = (slot_ids < sc["deep_pbe"][0]) & (dstate_row == DIRTY)
    return jnp.sum(live.astype(jnp.float64))
