"""Op handlers of the timed engine: one function per trace-op kind.

Each handler maps ``(ctx, MachineState) -> MachineState`` for the op the
selected core issues at time ``ctx.t``.  The step driver dispatches over
the op kind with ``jax.lax.switch``; *within* the PM-read and persist
handlers a second ``lax.switch`` dispatches over the **traced** scheme
scalar (NoPB / PB / PB_RF), so mixed-scheme grids share one XLA program.

PM write acks are modeled lazily: when a drain is scheduled its ack
arrival time at the switch is computed immediately (PM queueing
included) and stored per entry; any later event observes Drain->Empty
transitions whose ack time has passed (``policy.lazy_free``).  This
reproduces the paper's PI-buffer ack-priority rule (acks never wait
behind stalled writes) with one scan step per trace op.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import chain, channels, fabric, policy
from repro.core.params import spine_defer
from repro.core.engine.state import (DIRTY, DRAIN, EMPTY, INF, H_COALESCES,
                                     H_FWD_CNT, H_FWD_SUM, H_READ_HITS,
                                     MachineState, S_ACKED, S_COALESCES,
                                     S_DRAM_READS, S_DURABLE, S_LAT_HIST0,
                                     S_PBCQ_SUM, S_PERSIST_CNT,
                                     S_PERSIST_SUM, S_PI_DETOURS,
                                     S_PM_WRITES, S_READ_CNT, S_READ_HITS,
                                     S_READ_SUM, S_SLO_OVER, S_STALL_TIME,
                                     S_VICTIM_CNT, lat_bin)


class StepCtx(NamedTuple):
    """Per-step context handed to every handler."""

    c: jnp.ndarray          # ()  selected core
    t: jnp.ndarray          # ()  op issue time (core clock + compute gap)
    addr: jnp.ndarray       # ()  target cache line
    scheme: jnp.ndarray     # ()  i32 traced scheme id (Scheme value)
    sc: Dict[str, jnp.ndarray]  # traced latency/policy scalars
    slot_ids: jnp.ndarray   # (P,) arange over PBE slots
    slot_active: jnp.ndarray  # (P,) live-slot mask (slot_ids < n_pbe)
    tenant: jnp.ndarray     # ()  i32 tenant id of the selected core
    tids: jnp.ndarray       # (C,) i32 per-core tenant ids (traced)
    n_live_t: jnp.ndarray   # ()  live cores in this op's tenant (barriers)
    n_banks: int            # static PM bank count
    n_track: int = 0        # static durability-tracked address count


def _tracked(ctx: StepCtx, addr):
    """Is ``addr`` inside the durability-tracked window [0, n_track)?"""
    return (addr >= 0) & (addr < ctx.n_track)


# ---------------------------------------------------------------- volatile
def handle_compute(ctx: StepCtx, st: MachineState) -> MachineState:
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t))


def handle_dram_read(ctx: StepCtx, st: MachineState) -> MachineState:
    stats = st.stats.at[ctx.tenant, S_DRAM_READS].add(1.0)
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t + ctx.sc["dram_ns"]),
                       stats=stats)


def handle_dram_write(ctx: StepCtx, st: MachineState) -> MachineState:
    # posted write: ~free for the core
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t))


# ----------------------------------------------------------------- PM read
def handle_pm_read(ctx: StepCtx, st: MachineState) -> MachineState:
    sc, t, addr = ctx.sc, ctx.t, ctx.addr
    ow = sc["ow_cpu_pm"]
    bank = channels.bank_of(addr, ctx.n_banks)

    def direct(st: MachineState) -> MachineState:
        # NoPB: the volatile switch forwards every read to PM.
        pm_start = channels.service_start(st.pm_busy, bank, t + ow)
        resp = pm_start + sc["nvm_read"] + ow
        stats = st.stats.at[
            ctx.tenant, jnp.asarray([S_READ_SUM, S_READ_CNT], jnp.int32)
        ].add(jnp.stack([resp - t, jnp.ones((), jnp.float64)]))
        return st._replace(
            clock=st.clock.at[ctx.c].set(resp),
            pm_busy=channels.reserve(st.pm_busy, bank, pm_start,
                                     sc["nvm_r_occ"]),
            stats=stats)

    def via_pb(st: MachineState) -> MachineState:
        # PB/PB_RF: the PBCS classifies the read; a live entry routes it
        # through the PI buffer to the PBC (read forwarding).
        pm_start_dir = channels.service_start(st.pm_busy, bank, t + ow)
        resp_dir = pm_start_dir + sc["nvm_read"] + ow

        state0 = policy.lazy_free(st.state, st.dd, t)
        # Fabric: a read routes through the issuing tenant's own leaf
        # switch — only that leaf's slot window is visible, and that
        # leaf's PBC front serves it.  NL == 0 (chain-only grid) keeps
        # the global window and the shared scalar clock, byte-identical.
        NL = st.lpbc.shape[0]
        if NL > 0:
            my_leaf = fabric.leaf_of_tenant(sc, ctx.tenant)
            leaf_act = ctx.slot_active & fabric.leaf_mask(
                sc, fabric.slot_leaf(sc, ctx.slot_ids), my_leaf)
            pbc_prev = st.lpbc[my_leaf]
        else:
            leaf_act = ctx.slot_active
            pbc_prev = st.pbc_busy
        has, idx = policy.pb_lookup(st.tag, state0, leaf_act, addr)
        # PI-buffer path: wait for the PBC (head-of-line blocking)
        arr = t + sc["ow_cpu_sw1"]
        pbc_start = channels.pbc_start(pbc_prev, arr,
                                       sc["pbc_read_ns"] + sc["tag_ns"])
        st_i = state0[idx]
        dd_i = st.dd[idx]
        served = (st_i == DIRTY) | (
            (st_i == DRAIN) & (dd_i > pbc_start + sc["fwd_margin"]))
        resp_pb = pbc_start + sc["data_ns"] + sc["ow_cpu_sw1"]
        # forwarded to PM through the PO buffer after the detour; the
        # packet re-enters the routing pipeline (one extra pipe pass)
        pm_start_fwd = jnp.maximum(
            st.pm_busy[bank],
            pbc_start + sc["switch_pipe"] + sc["ow_sw1_pm"])
        resp_fwd = pm_start_fwd + sc["nvm_read"] + ow

        # Read-forwarding checks below hop 1 (switch chain): when hop 1
        # has no live entry, the packet travels toward PM passing every
        # deeper switch's PBCS — the shallowest hop holding a visible
        # live entry serves it.  (A *stale* hop-1 Drain entry keeps its
        # legacy forward-to-PM path: the deep refinement is skipped.)
        D = st.dtag.shape[0]
        if D > 0:
            dhit0, resp_deep, dlru2, hrow = chain.deep_read(sc, st, addr, t)
            deep_hit = (sc["n_switches"] >= 2.0) & dhit0 & ~has
        else:
            deep_hit = jnp.asarray(False)
            resp_deep, dlru2, hrow = resp_dir, st.dlru, 0

        resp = jnp.where(has, jnp.where(served, resp_pb, resp_fwd),
                         jnp.where(deep_hit, resp_deep, resp_dir))
        pm_busy2 = st.pm_busy.at[bank].set(jnp.where(
            has,
            jnp.where(served, st.pm_busy[bank],
                      pm_start_fwd + sc["nvm_r_occ"]),
            jnp.where(deep_hit, st.pm_busy[bank],
                      pm_start_dir + sc["nvm_r_occ"])))
        pbc_busy2 = jnp.where(
            has, channels.pbc_hold(pbc_prev, arr, sc["pbc_read_occ"]),
            pbc_prev)
        if NL > 0:
            pbc_kw = dict(lpbc=st.lpbc.at[my_leaf].set(pbc_busy2))
        else:
            pbc_kw = dict(pbc_busy=pbc_busy2)
        lru2 = st.lru.at[idx].set(jnp.where(has & served, t, st.lru[idx]))
        dlru3 = jnp.where(deep_hit, dlru2, st.dlru)
        hop_stats = st.hop_stats.at[0, H_READ_HITS].add(
            (has & served).astype(jnp.float64))
        if D > 0:
            hop_stats = hop_stats.at[hrow + 1, H_READ_HITS].add(
                deep_hit.astype(jnp.float64))
        stats = st.stats.at[
            ctx.tenant, jnp.asarray([S_READ_SUM, S_READ_CNT, S_READ_HITS,
                                     S_PI_DETOURS], jnp.int32)
        ].add(jnp.stack([resp - t, jnp.ones((), jnp.float64),
                         ((has & served) | deep_hit).astype(jnp.float64),
                         has.astype(jnp.float64)]))
        return st._replace(clock=st.clock.at[ctx.c].set(resp), state=state0,
                           lru=lru2, dlru=dlru3, pm_busy=pm_busy2,
                           stats=stats, hop_stats=hop_stats, **pbc_kw)

    return jax.lax.switch(jnp.minimum(ctx.scheme, 1), [direct, via_pb], st)


# ----------------------------------------------------------------- persist
def _persist_with_buffer(ctx: StepCtx, st: MachineState) -> MachineState:
    """Shared PB persist core: PBC service, lookup, allocation / victim
    selection, entry write — then the scheme's drain policy.

    One traced body serves both buffered schemes: ``is_rf`` selects
    coalescing and the threshold/preset drain policy (PB_RF) vs the
    immediate write-through drain (PB) elementwise.  Tracing this once
    instead of once per scheme halves the vmap-executed switch-chain
    work per step (vmap runs every ``lax.switch`` branch), which is the
    dominant cost of the scan body at depth >= 2.
    """
    sc, t, addr = ctx.sc, ctx.t, ctx.addr
    is_rf = ctx.scheme == 2          # Scheme.PB_RF, traced
    crash = sc["crash_at"]
    bank = channels.bank_of(addr, ctx.n_banks)
    arr = t + sc["ow_cpu_sw1"]
    # Fabric: the persist enters the issuing tenant's own leaf switch —
    # lookup/alloc/victim/drain are scoped to that leaf's slot window,
    # and that leaf's own PBC front serves the packet.  NL == 0 (no
    # fabric anywhere in the grid) keeps the global window and the
    # shared scalar clock, byte-identical to the chain engine; a chain
    # cell *inside* a fabric grid gets the same via the n_leaves < 2
    # mask bypass (every slot maps to leaf 0).
    NL = st.lpbc.shape[0]
    if NL > 0:
        my_leaf = fabric.leaf_of_tenant(sc, ctx.tenant)
        leaf_act = ctx.slot_active & fabric.leaf_mask(
            sc, fabric.slot_leaf(sc, ctx.slot_ids), my_leaf)
        pbc_prev = st.lpbc[my_leaf]
    else:
        leaf_act = ctx.slot_active
        pbc_prev = st.pbc_busy
    pbc_start = channels.pbc_start(pbc_prev, arr,
                                   sc["pbc_proc_ns"] + sc["tag_ns"])
    state1 = policy.lazy_free(st.state, st.dd, pbc_start)
    match_dirty = leaf_act & (st.tag == addr) & (state1 == DIRTY)
    has_dirty = jnp.any(match_dirty)
    idx = jnp.argmax(match_dirty)

    # durability tracking: this persist's per-address version number
    A = st.aver.shape[0]
    tracked = _tracked(ctx, addr)
    a_idx = jnp.clip(addr, 0, A - 1)
    v_new = st.aver[a_idx] + 1
    aver2 = st.aver.at[a_idx].add(jnp.where(tracked, 1, 0))

    is_coalesce = jnp.logical_and(is_rf, has_dirty)
    # An in-flight (Drain) older version does NOT block the new persist
    # (write order, Section IV-A): the new version gets its own entry.
    # The switch->PM path is FIFO per bank, so drains of the same line
    # arrive at PM in version order without waiting for the previous ack.
    # Allocation is policy-driven (AllocPolicy lowering): per-tenant
    # occupancy feeds the quota gate and the weighted victim selection.
    occ = policy.tenant_occupancy(state1, ctx.slot_active, st.owner,
                                  st.stats.shape[0])
    (any_empty, empty_idx, any_dirty, victim_idx,
     earliest_idx) = policy.select_slot(sc, state1, leaf_act,
                                        st.lru, st.dd, st.owner,
                                        ctx.tenant, occ)

    # victim drain (only used when no Empty entry exists)
    victim_bank = channels.bank_of(st.tag[victim_idx], ctx.n_banks)
    victim_pm_start = jnp.maximum(st.pm_busy[victim_bank],
                                  pbc_start + sc["ow_sw1_pm"])
    victim_dd = victim_pm_start + sc["nvm_write"] + sc["ow_sw1_pm"]
    needs_victim = (~is_coalesce) & (~any_empty) & any_dirty

    # the victim's in-flight write is durable at PM iff its ack beats the
    # crash (a later ack means the write is lost with the power)
    vic_tag = st.tag[victim_idx]
    vic_ok = (needs_victim & (victim_dd <= crash) & (vic_tag >= 0)
              & (vic_tag < ctx.n_track))
    pm_ver1 = st.pm_ver.at[jnp.clip(vic_tag, 0, A - 1)].max(
        jnp.where(vic_ok, st.ver[victim_idx], 0))

    # ---- switch chain, victim leg (per-switch persistent buffers) -----
    # With >= 2 switches in the chain, a hop-1 drain is acked by hop 2's
    # persistent cells, not by PM: the victim packet travels the chain
    # FIRST (it leaves the PBC at pbc_start, ahead of the entry write),
    # so the slot frees at its true downstream ack.  D == 0 (no deep row
    # allocated anywhere in the grid) skips the chain at trace time.
    D = st.dtag.shape[0]
    vic_emit = needs_victim & (pbc_start <= crash)
    if D > 0:
        is_chain = sc["n_switches"] >= 2.0
        one_i = lambda v: jnp.asarray([v], jnp.int32)        # noqa: E731
        vic_batch = chain.Batch(
            active=vic_emit[None],
            addr=vic_tag[None], ver=st.ver[victim_idx][None],
            owner=st.owner[victim_idx][None], emit=pbc_start[None],
            ohop=one_i(0), oslot=victim_idx[None].astype(jnp.int32))
        (dd_v, rows_v, hpbc_v, hstats_v, pmb_v, pmv_v,
         pmw_v) = chain.forward_chain(
            sc, ctx.scheme, chain.rows_of(st), st.hpbc, st.hop_stats,
            vic_batch, st.dd, st.pm_busy, st.pm_ver,
            n_banks=ctx.n_banks, n_track=ctx.n_track)
        vic_ack = jnp.where(vic_emit, dd_v[victim_idx], victim_dd)
        vic_wait = jnp.where(is_chain, vic_ack, victim_dd)
    else:
        vic_wait = victim_dd

    slot = jnp.where(any_empty, empty_idx,
                     jnp.where(any_dirty, victim_idx, earliest_idx))
    ta = jnp.where(any_empty, pbc_start,
                   jnp.where(any_dirty, vic_wait,
                             jnp.maximum(pbc_start, st.dd[earliest_idx])))
    pm_busy1 = st.pm_busy.at[victim_bank].set(jnp.where(
        needs_victim, victim_pm_start + sc["nvm_w_occ"],
        st.pm_busy[victim_bank]))
    state2 = jnp.where(
        needs_victim & (ctx.slot_ids == victim_idx), DRAIN, state1)
    dd2 = jnp.where(
        needs_victim & (ctx.slot_ids == victim_idx), victim_dd, st.dd)

    # write the entry (new allocation or coalesce-in-place)
    wslot = jnp.where(is_coalesce, idx, slot)
    t_written = jnp.where(is_coalesce, pbc_start, ta) + sc["data_ns"]
    ack = t_written + sc["ow_cpu_sw1"]
    # Serving-SLO drain tightening (DrainPolicy.latency_target_ns): the
    # running over-target fraction *including this persist* decides
    # whether this op's drain-down runs tight.  With no target the
    # lowered scalar is INF, over_now is always 0 and tight is always
    # false — bit-exact with the pre-SLO engine.
    lat = ack - t
    over_now = (lat > sc["lat_target"]).astype(jnp.float64)  # lint: mirror(slo-over)
    cnt1 = st.stats[ctx.tenant, S_PERSIST_CNT] + 1.0  # lint: mirror(slo-cnt)
    over1 = st.stats[ctx.tenant, S_SLO_OVER] + over_now  # lint: mirror(slo-run)
    tight = over1 > sc["lat_tol"] * cnt1  # lint: mirror(slo-tight)
    state3 = jnp.where(ctx.slot_ids == wslot, DIRTY, state2)
    tag3 = st.tag.at[wslot].set(addr)
    lru3 = st.lru.at[wslot].set(t_written)
    dd3 = dd2
    ver3 = st.ver.at[wslot].set(v_new)
    # the writer takes ownership (a cross-tenant coalesce included,
    # mirroring the oracle's PBEntry.tenant update)
    owner3 = st.owner.at[wslot].set(ctx.tenant.astype(st.owner.dtype))

    # Backpressure-aware drain scheduling (fabric): while the spine PB's
    # live occupancy — measured AFTER this op's victim leg landed, i.e.
    # what the leaf's drain batch would actually meet — is at/above the
    # topology's bp_high, the leaf's threshold/low-water drain-down
    # defers (holds its Dirty entries) instead of piling more fan-in
    # onto the congested spine.  Non-fabric configs lower bp_high = INF
    # (never defer); victim drains and PB's drain-immediate are exempt
    # (forward progress).
    if D > 0 and NL > 0:
        sp_live = fabric.spine_live(sc, rows_v["dstate"][0], ctx.slot_ids)
        defer = spine_defer(sp_live, sc["bp_high"])
    else:
        defer = None

    # Both drain policies run (cheap relative to the chain legs); the
    # traced scheme bit picks each output elementwise, bit-exactly.
    state4_pb, dd4_pb, pmb2_pb, pw_pb = policy.drain_immediate(
        sc, bank, ctx.slot_ids, wslot, t_written, state3, dd3, pm_busy1)
    state4_rf, dd4_rf, pmb2_rf, pw_rf = policy.drain_threshold_preset(
        sc, ctx.n_banks, leaf_act, t_written, state3, tag3, lru3,
        dd3, pm_busy1, owner=owner3, tenant=ctx.tenant, tight=tight,
        defer=defer)
    state4 = jnp.where(is_rf, state4_rf, state4_pb)
    dd4 = jnp.where(is_rf, dd4_rf, dd4_pb)
    pm_busy2 = jnp.where(is_rf, pmb2_rf, pmb2_pb)
    policy_writes = jnp.where(is_rf, pw_rf, pw_pb)

    # drains the policy just scheduled (Dirty -> Drain) whose PM ack
    # beats the crash make their versions durable at the device
    drained_now = (state4 == DRAIN) & (state3 == DIRTY)
    drain_ok = (drained_now & (dd4 <= crash) & (tag3 >= 0)
                & (tag3 < ctx.n_track))
    pm_ver2 = pm_ver1.at[jnp.clip(tag3, 0, A - 1)].max(
        jnp.where(drain_ok, ver3, 0))

    # Switch-commit gate: a persist that issued before the crash but
    # whose entry write lands only after it never reached the
    # persistent switch.  Its PB-table effects (allocation, coalesce,
    # policy drains) are discarded — otherwise it would overwrite a
    # surviving entry whose in-flight drain is lost, dropping an acked
    # version from the durable state.  The victim drain stands if the
    # PBC emitted it before the power loss (its entry then survives in
    # Drain when its ack is post-crash, so its version is never lost),
    # and a non-committed persist consumes no version number.  Resource
    # clocks (PBC/PM/core) stay as computed: the packet occupied them
    # until the power died, and the core is dead afterwards anyway.
    commit = t_written <= crash
    vslot = ctx.slot_ids == victim_idx
    state5 = jnp.where(commit, state4,
                       jnp.where(vic_emit & vslot, DRAIN, st.state))
    tag5 = jnp.where(commit, tag3, st.tag)
    lru5 = jnp.where(commit, lru3, st.lru)
    dd5 = jnp.where(commit, dd4,
                    jnp.where(vic_emit & vslot, victim_dd, st.dd))
    ver5 = jnp.where(commit, ver3, st.ver)
    owner5 = jnp.where(commit, owner3, st.owner)
    aver3 = jnp.where(commit, aver2, st.aver)
    pm_ver3 = jnp.where(commit, pm_ver2, pm_ver1)
    pm_busy3 = jnp.where(commit, pm_busy2, pm_busy1)
    pm_writes_inc = (vic_emit.astype(jnp.float64)
                     + jnp.where(commit, policy_writes, 0.0))

    # ---- switch chain, policy-drain leg --------------------------------
    # The drains the policy just scheduled travel to hop 2 as one batch
    # (they leave the PBC together at t_written, after the victim leg);
    # under the chain the PM-path dd/pm values computed above are
    # per-field replaced by the cascade's downstream acks and landings.
    if D > 0:
        P = st.tag.shape[0]
        # the batch leaves the PBC in LRU order of the drained entries
        # (the wire order the oracle's drain-down replays)
        pol_active = drained_now & commit
        pol_order = jnp.argsort(
            jnp.where(pol_active, lru3, INF)).astype(jnp.int32)
        pol_batch = chain.Batch(
            active=pol_active[pol_order],
            addr=tag3[pol_order], ver=ver3[pol_order],
            owner=owner3[pol_order],
            emit=jnp.zeros((P,), jnp.float64) + t_written,
            ohop=jnp.zeros((P,), jnp.int32),
            oslot=pol_order)
        (dd_c, rows_c, hpbc_c, hstats_c, pmb_c, pmv_c,
         pmw_c) = chain.forward_chain(
            sc, ctx.scheme, rows_v, hpbc_v, hstats_v, pol_batch,
            jnp.where(commit, dd4, dd_v), pmb_v, pmv_v,
            n_banks=ctx.n_banks, n_track=ctx.n_track)
        dd5 = jnp.where(is_chain, dd_c, dd5)
        pm_ver3 = jnp.where(is_chain, pmv_c, pm_ver3)
        pm_busy3 = jnp.where(is_chain, pmb_c, pm_busy3)
        pm_writes_inc = jnp.where(is_chain, pmw_v + pmw_c, pm_writes_inc)
        chain_cols = {k: jnp.where(is_chain, rows_c[k], getattr(st, k))
                      for k in rows_c}
        chain_cols["hpbc"] = jnp.where(is_chain, hpbc_c, st.hpbc)
        hop_stats = jnp.where(is_chain, hstats_c, st.hop_stats)
    else:
        chain_cols = {}
        hop_stats = st.hop_stats
    # hop-1 telemetry row (chain row 0; maintained at every depth >= 1)
    hop_stats = hop_stats.at[0, H_FWD_CNT].add(commit.astype(jnp.float64))
    hop_stats = hop_stats.at[0, H_FWD_SUM].add(
        jnp.where(commit, t_written - arr, 0.0))
    hop_stats = hop_stats.at[0, H_COALESCES].add(
        (is_coalesce & commit).astype(jnp.float64))

    stall = jnp.where(is_coalesce, 0.0, ta - pbc_start)
    # Only a genuine Empty-shortage stall (ta > pbc_start) holds the PI
    # front beyond the pipelined issue interval.
    pbc_free = jnp.maximum(
        channels.pbc_hold(pbc_prev, arr, sc["pbc_occ_ns"]),
        jnp.where(is_coalesce | (ta <= pbc_start), 0.0, ta))
    if NL > 0:
        pbc_kw = dict(lpbc=st.lpbc.at[my_leaf].set(pbc_free))
    else:
        pbc_kw = dict(pbc_busy=pbc_free)
    # One fused scatter for every per-persist accumulator (all distinct
    # columns, so the sums are element-wise identical to chained adds —
    # the macro fast path stays bit-exact).  A persist committed into
    # the persistent switch is durable regardless of the drain's fate
    # (the paper's core claim); the core only *observes* the ack if it
    # lands before the crash, and ack beats the crash only if the write
    # committed first, so acked => durable.
    hist_col = (S_LAT_HIST0 + lat_bin(lat))[None]  # lint: mirror(lat-bin)
    cols = jnp.concatenate([
        jnp.asarray([S_VICTIM_CNT, S_PBCQ_SUM, S_PERSIST_SUM,
                     S_PERSIST_CNT, S_SLO_OVER, S_COALESCES, S_PM_WRITES,
                     S_STALL_TIME, S_ACKED, S_DURABLE], jnp.int32),
        hist_col])
    vals = jnp.stack([
        ((~is_coalesce) & (~any_empty)).astype(jnp.float64),
        jnp.maximum(pbc_prev - arr, 0.0),
        ack - t,
        jnp.ones((), jnp.float64),
        over_now,
        is_coalesce.astype(jnp.float64),
        pm_writes_inc,
        stall,
        (ack <= crash).astype(jnp.float64),
        commit.astype(jnp.float64),
        jnp.ones((), jnp.float64)])
    stats = st.stats.at[ctx.tenant, cols].add(vals)  # lint: mirror(stats-scatter)
    return st._replace(clock=st.clock.at[ctx.c].set(ack), tag=tag5,
                       state=state5, lru=lru5, dd=dd5, ver=ver5,
                       owner=owner5, aver=aver3, pm_ver=pm_ver3,
                       pm_busy=pm_busy3, stats=stats,
                       hop_stats=hop_stats, **pbc_kw, **chain_cols)


def handle_persist(ctx: StepCtx, st: MachineState) -> MachineState:
    sc, t, addr = ctx.sc, ctx.t, ctx.addr

    def nopb(st: MachineState) -> MachineState:
        # Volatile switch: the persist round-trips to PM.  Nothing is
        # durable until PM acks — a write whose ack lands after the
        # crash is lost (and the core never saw the ack either).
        ow = sc["ow_cpu_pm"]
        crash = sc["crash_at"]
        bank = channels.bank_of(addr, ctx.n_banks)
        pm_start = channels.service_start(st.pm_busy, bank, t + ow)
        ack = pm_start + sc["nvm_write"] + ow
        ok = ack <= crash
        A = st.aver.shape[0]
        tracked = _tracked(ctx, addr)
        a_idx = jnp.clip(addr, 0, A - 1)
        v_new = st.aver[a_idx] + 1
        # lint: exempt(stats-columns, S_COALESCES S_READ_HITS S_PI_DETOURS): no PB table on the volatile switch
        # lint: exempt(stats-columns, S_PBCQ_SUM S_STALL_TIME S_VICTIM_CNT): no PBC queue or eviction on the direct PM path
        lat = ack - t
        over_now = (lat > sc["lat_target"]).astype(jnp.float64)  # lint: mirror(slo-over)
        one = jnp.ones((), jnp.float64)
        hist_col = (S_LAT_HIST0 + lat_bin(lat))[None]  # lint: mirror(lat-bin)
        cols = jnp.concatenate([
            jnp.asarray([S_PERSIST_SUM, S_PERSIST_CNT, S_SLO_OVER,
                         S_PM_WRITES, S_ACKED, S_DURABLE], jnp.int32),
            hist_col])
        vals = jnp.stack([ack - t, one, over_now, one,
                          ok.astype(jnp.float64), ok.astype(jnp.float64),
                          one])
        stats = st.stats.at[ctx.tenant, cols].add(vals)  # lint: mirror(stats-scatter)
        return st._replace(
            clock=st.clock.at[ctx.c].set(ack),
            aver=st.aver.at[a_idx].add(jnp.where(tracked, 1, 0)),
            pm_ver=st.pm_ver.at[a_idx].max(
                jnp.where(tracked & ok, v_new, 0)),
            pm_busy=channels.reserve(st.pm_busy, bank, pm_start,
                                     sc["nvm_w_occ"]),
            stats=stats)

    def buffered(st: MachineState) -> MachineState:
        # PB and PB_RF share one traced body (is_rf inside selects the
        # coalesce rule and drain policy) so vmap executes the
        # expensive chain legs once per step instead of twice.
        return _persist_with_buffer(ctx, st)

    return jax.lax.switch(jnp.minimum(ctx.scheme, 1), [nopb, buffered], st)


# ----------------------------------------------------------------- barrier
def handle_barrier(ctx: StepCtx, st: MachineState) -> MachineState:
    # Centralized barrier *per tenant*: independent hosts never
    # synchronize with each other, so only this tenant's cores arrive
    # and the last of them releases its tenant's waiters at its arrival
    # time.  With one tenant this is exactly the old global barrier.
    same = ctx.tids == ctx.tenant
    last = (st.bcount[ctx.tenant] + 1) >= ctx.n_live_t
    released = jnp.where(st.blocked & same, ctx.t,
                         st.clock).at[ctx.c].set(ctx.t)
    waiting = st.clock.at[ctx.c].set(INF * 0.9)
    return st._replace(clock=jnp.where(last, released, waiting))


HANDLERS = [handle_compute, handle_dram_read, handle_dram_write,
            handle_pm_read, handle_persist, handle_barrier]


# ---------------------------------------------------------------- recovery
def recovery_snapshot(st: MachineState, scheme, sc, slot_active,
                      n_banks: int, n_track: int):
    """Section V-D4 recovery pass over the crash-time machine state.

    Dispatches over the traced scheme like the op handlers: NoPB has no
    PBEs, so its durable state is exactly ``pm_ver`` and recovery is
    free; PB/PB_RF drain-all the *union* of surviving Dirty/Drain
    entries across every hop of the switch chain — a crash freezes each
    hop independently, and durability per address is the newest version
    held at any surviving hop (or PM).  Returns
    ``(durable_ver (A,) i32, n_recovered f64, recovery_ns f64,
    recovered_per_tenant (T,) f64, recovered_per_hop (D+1,) f64,
    recovered_per_leaf (max(NL,1),) f64)`` — the last three attribute
    each surviving entry to its owning tenant (recovery fairness,
    ROADMAP), to the hop holding it (the chain depth figure), and —
    for fan-out fabrics — to the leaf switch holding it (hop-1 slots
    scattered by their leaf window; the spine's survivors are
    ``per_hop[1]``).
    """
    crash = sc["crash_at"]
    A = st.pm_ver.shape[0]
    T = st.stats.shape[0]
    D = st.dtag.shape[0]
    NL = max(st.lpbc.shape[0], 1)
    zero = jnp.asarray(0.0, jnp.float64)
    zero_t = jnp.zeros((T,), jnp.float64)
    zero_h = jnp.zeros((D + 1,), jnp.float64)
    zero_l = jnp.zeros((NL,), jnp.float64)

    def nopb(_):
        return st.pm_ver, zero, zero, zero_t, zero_h, zero_l

    def pb(_):
        surviving = policy.surviving_entries(st.state, st.dd, slot_active,
                                             crash)
        in_range = surviving & (st.tag >= 0) & (st.tag < n_track)
        dv = st.pm_ver.at[jnp.clip(st.tag, 0, A - 1)].max(
            jnp.where(in_range, st.ver, 0))
        per_t = zero_t.at[jnp.clip(st.owner, 0, T - 1)].add(
            surviving.astype(jnp.float64))
        if st.lpbc.shape[0] > 0:
            sl = fabric.slot_leaf(sc, jnp.arange(st.tag.shape[0]))
            per_leaf = zero_l.at[sl].add(surviving.astype(jnp.float64))
        else:
            per_leaf = zero_l.at[0].set(
                jnp.sum(surviving.astype(jnp.float64)))
        B = n_banks
        banks = jnp.where(surviving, st.tag % B, 0)
        per_bank = jnp.zeros((B,), jnp.float64).at[banks].add(
            surviving.astype(jnp.float64))
        n = jnp.sum(surviving.astype(jnp.float64))
        per_hop = zero_h.at[0].set(n)
        slot_ids = jnp.arange(st.tag.shape[0])
        for j in range(D):
            row_live = (float(j) + 2.0) <= sc["n_switches"]
            sa = slot_ids < sc["deep_pbe"][j].astype(jnp.int32)
            # same survival rule per hop: Dirty cells persist; a Drain
            # entry survives iff its downstream ack is lost with the
            # power (placements are commit-gated, so wt <= crash always
            # holds — kept as written defence)
            surv_j = (row_live & sa & (st.dwt[j] <= crash)
                      & ((st.dstate[j] == DIRTY)
                         | ((st.dstate[j] == DRAIN) & (st.ddd[j] > crash))))
            in_r = surv_j & (st.dtag[j] >= 0) & (st.dtag[j] < n_track)
            dv = dv.at[jnp.clip(st.dtag[j], 0, A - 1)].max(
                jnp.where(in_r, st.dver[j], 0))
            per_t = per_t.at[jnp.clip(st.downer[j], 0, T - 1)].add(
                surv_j.astype(jnp.float64))
            bj = jnp.where(surv_j, st.dtag[j] % B, 0)
            per_bank = per_bank.at[bj].add(surv_j.astype(jnp.float64))
            nj = jnp.sum(surv_j.astype(jnp.float64))
            per_hop = per_hop.at[j + 1].set(nj)
        n_total = jnp.sum(per_hop)
        cost = policy.recovery_burst_cost(sc, per_bank, n_total)
        return dv, n_total, cost, per_t, per_hop, per_leaf

    return jax.lax.switch(jnp.minimum(scheme, 1), [nopb, pb], None)
