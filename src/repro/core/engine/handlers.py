"""Op handlers of the timed engine: one function per trace-op kind.

Each handler maps ``(ctx, MachineState) -> MachineState`` for the op the
selected core issues at time ``ctx.t``.  The step driver dispatches over
the op kind with ``jax.lax.switch``; *within* the PM-read and persist
handlers a second ``lax.switch`` dispatches over the **traced** scheme
scalar (NoPB / PB / PB_RF), so mixed-scheme grids share one XLA program.

PM write acks are modeled lazily: when a drain is scheduled its ack
arrival time at the switch is computed immediately (PM queueing
included) and stored per entry; any later event observes Drain->Empty
transitions whose ack time has passed (``policy.lazy_free``).  This
reproduces the paper's PI-buffer ack-priority rule (acks never wait
behind stalled writes) with one scan step per trace op.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import channels, policy
from repro.core.engine.state import (DIRTY, DRAIN, EMPTY, INF, MachineState,
                                     S_COALESCES, S_DRAM_READS, S_PBCQ_SUM,
                                     S_PERSIST_CNT, S_PERSIST_SUM,
                                     S_PI_DETOURS, S_PM_WRITES, S_READ_CNT,
                                     S_READ_HITS, S_READ_SUM, S_STALL_TIME,
                                     S_VICTIM_CNT)


class StepCtx(NamedTuple):
    """Per-step context handed to every handler."""

    c: jnp.ndarray          # ()  selected core
    t: jnp.ndarray          # ()  op issue time (core clock + compute gap)
    addr: jnp.ndarray       # ()  target cache line
    scheme: jnp.ndarray     # ()  i32 traced scheme id (Scheme value)
    sc: Dict[str, jnp.ndarray]  # traced latency/policy scalars
    slot_ids: jnp.ndarray   # (P,) arange over PBE slots
    slot_active: jnp.ndarray  # (P,) live-slot mask (slot_ids < n_pbe)
    n_live: jnp.ndarray     # ()  number of cores participating in barriers
    n_banks: int            # static PM bank count


# ---------------------------------------------------------------- volatile
def handle_compute(ctx: StepCtx, st: MachineState) -> MachineState:
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t))


def handle_dram_read(ctx: StepCtx, st: MachineState) -> MachineState:
    stats = st.stats.at[S_DRAM_READS].add(1.0)
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t + ctx.sc["dram_ns"]),
                       stats=stats)


def handle_dram_write(ctx: StepCtx, st: MachineState) -> MachineState:
    # posted write: ~free for the core
    return st._replace(clock=st.clock.at[ctx.c].set(ctx.t))


# ----------------------------------------------------------------- PM read
def handle_pm_read(ctx: StepCtx, st: MachineState) -> MachineState:
    sc, t, addr = ctx.sc, ctx.t, ctx.addr
    ow = sc["ow_cpu_pm"]
    bank = channels.bank_of(addr, ctx.n_banks)

    def direct(st: MachineState) -> MachineState:
        # NoPB: the volatile switch forwards every read to PM.
        pm_start = channels.service_start(st.pm_busy, bank, t + ow)
        resp = pm_start + sc["nvm_read"] + ow
        stats = st.stats.at[S_READ_SUM].add(resp - t)
        stats = stats.at[S_READ_CNT].add(1.0)
        return st._replace(
            clock=st.clock.at[ctx.c].set(resp),
            pm_busy=channels.reserve(st.pm_busy, bank, pm_start,
                                     sc["nvm_r_occ"]),
            stats=stats)

    def via_pb(st: MachineState) -> MachineState:
        # PB/PB_RF: the PBCS classifies the read; a live entry routes it
        # through the PI buffer to the PBC (read forwarding).
        pm_start_dir = channels.service_start(st.pm_busy, bank, t + ow)
        resp_dir = pm_start_dir + sc["nvm_read"] + ow

        state0 = policy.lazy_free(st.state, st.dd, t)
        has, idx = policy.pb_lookup(st.tag, state0, ctx.slot_active, addr)
        # PI-buffer path: wait for the PBC (head-of-line blocking)
        arr = t + sc["ow_cpu_sw1"]
        pbc_start = channels.pbc_start(st.pbc_busy, arr,
                                       sc["pbc_read_ns"] + sc["tag_ns"])
        st_i = state0[idx]
        dd_i = st.dd[idx]
        served = (st_i == DIRTY) | (
            (st_i == DRAIN) & (dd_i > pbc_start + sc["fwd_margin"]))
        resp_pb = pbc_start + sc["data_ns"] + sc["ow_cpu_sw1"]
        # forwarded to PM through the PO buffer after the detour; the
        # packet re-enters the routing pipeline (one extra pipe pass)
        pm_start_fwd = jnp.maximum(
            st.pm_busy[bank],
            pbc_start + sc["switch_pipe"] + sc["ow_sw1_pm"])
        resp_fwd = pm_start_fwd + sc["nvm_read"] + ow

        resp = jnp.where(has, jnp.where(served, resp_pb, resp_fwd),
                         resp_dir)
        pm_busy2 = st.pm_busy.at[bank].set(jnp.where(
            has,
            jnp.where(served, st.pm_busy[bank],
                      pm_start_fwd + sc["nvm_r_occ"]),
            pm_start_dir + sc["nvm_r_occ"]))
        pbc_busy2 = jnp.where(
            has, channels.pbc_hold(st.pbc_busy, arr, sc["pbc_read_occ"]),
            st.pbc_busy)
        lru2 = st.lru.at[idx].set(jnp.where(has & served, t, st.lru[idx]))
        stats = st.stats.at[S_READ_SUM].add(resp - t)
        stats = stats.at[S_READ_CNT].add(1.0)
        stats = stats.at[S_READ_HITS].add((has & served).astype(jnp.float64))
        stats = stats.at[S_PI_DETOURS].add(has.astype(jnp.float64))
        return st._replace(clock=st.clock.at[ctx.c].set(resp), state=state0,
                           lru=lru2, pm_busy=pm_busy2, pbc_busy=pbc_busy2,
                           stats=stats)

    return jax.lax.switch(jnp.minimum(ctx.scheme, 1), [direct, via_pb], st)


# ----------------------------------------------------------------- persist
def _persist_with_buffer(ctx: StepCtx, st: MachineState,
                         coalesce_enabled: bool,
                         drain_policy) -> MachineState:
    """Shared PB persist core: PBC service, lookup, allocation / victim
    selection, entry write — then the scheme's drain policy."""
    sc, t, addr = ctx.sc, ctx.t, ctx.addr
    bank = channels.bank_of(addr, ctx.n_banks)
    arr = t + sc["ow_cpu_sw1"]
    pbc_start = channels.pbc_start(st.pbc_busy, arr,
                                   sc["pbc_proc_ns"] + sc["tag_ns"])
    state1 = policy.lazy_free(st.state, st.dd, pbc_start)
    match_dirty = ctx.slot_active & (st.tag == addr) & (state1 == DIRTY)
    has_dirty = jnp.any(match_dirty)
    idx = jnp.argmax(match_dirty)

    is_coalesce = jnp.logical_and(coalesce_enabled, has_dirty)
    # An in-flight (Drain) older version does NOT block the new persist
    # (write order, Section IV-A): the new version gets its own entry.
    # The switch->PM path is FIFO per bank, so drains of the same line
    # arrive at PM in version order without waiting for the previous ack.
    (any_empty, empty_idx, any_dirty, victim_idx,
     earliest_idx) = policy.select_slot(state1, ctx.slot_active, st.lru,
                                        st.dd)

    # victim drain (only used when no Empty entry exists)
    victim_bank = channels.bank_of(st.tag[victim_idx], ctx.n_banks)
    victim_pm_start = jnp.maximum(st.pm_busy[victim_bank],
                                  pbc_start + sc["ow_sw1_pm"])
    victim_dd = victim_pm_start + sc["nvm_write"] + sc["ow_sw1_pm"]
    needs_victim = (~is_coalesce) & (~any_empty) & any_dirty

    slot = jnp.where(any_empty, empty_idx,
                     jnp.where(any_dirty, victim_idx, earliest_idx))
    ta = jnp.where(any_empty, pbc_start,
                   jnp.where(any_dirty, victim_dd,
                             jnp.maximum(pbc_start, st.dd[earliest_idx])))
    pm_busy1 = st.pm_busy.at[victim_bank].set(jnp.where(
        needs_victim, victim_pm_start + sc["nvm_w_occ"],
        st.pm_busy[victim_bank]))
    state2 = jnp.where(
        needs_victim & (ctx.slot_ids == victim_idx), DRAIN, state1)
    dd2 = jnp.where(
        needs_victim & (ctx.slot_ids == victim_idx), victim_dd, st.dd)

    # write the entry (new allocation or coalesce-in-place)
    wslot = jnp.where(is_coalesce, idx, slot)
    t_written = jnp.where(is_coalesce, pbc_start, ta) + sc["data_ns"]
    ack = t_written + sc["ow_cpu_sw1"]
    state3 = jnp.where(ctx.slot_ids == wslot, DIRTY, state2)
    tag3 = st.tag.at[wslot].set(addr)
    lru3 = st.lru.at[wslot].set(t_written)
    dd3 = dd2

    state4, dd4, pm_busy2, policy_writes = drain_policy(
        bank=bank, wslot=wslot, t_written=t_written, state3=state3,
        tag3=tag3, lru3=lru3, dd3=dd3, pm_busy1=pm_busy1)
    pm_writes_inc = needs_victim.astype(jnp.float64) + policy_writes

    stall = jnp.where(is_coalesce, 0.0, ta - pbc_start)
    stats = st.stats.at[S_VICTIM_CNT].add(
        ((~is_coalesce) & (~any_empty)).astype(jnp.float64))
    stats = stats.at[S_PBCQ_SUM].add(
        jnp.maximum(st.pbc_busy - arr, 0.0))
    # Only a genuine Empty-shortage stall (ta > pbc_start) holds the PI
    # front beyond the pipelined issue interval.
    pbc_free = jnp.maximum(
        channels.pbc_hold(st.pbc_busy, arr, sc["pbc_occ_ns"]),
        jnp.where(is_coalesce | (ta <= pbc_start), 0.0, ta))
    stats = stats.at[S_PERSIST_SUM].add(ack - t)
    stats = stats.at[S_PERSIST_CNT].add(1.0)
    stats = stats.at[S_COALESCES].add(is_coalesce.astype(jnp.float64))
    stats = stats.at[S_PM_WRITES].add(pm_writes_inc)
    stats = stats.at[S_STALL_TIME].add(stall)
    return st._replace(clock=st.clock.at[ctx.c].set(ack), tag=tag3,
                       state=state4, lru=lru3, dd=dd4, pm_busy=pm_busy2,
                       pbc_busy=pbc_free, stats=stats)


def handle_persist(ctx: StepCtx, st: MachineState) -> MachineState:
    sc, t, addr = ctx.sc, ctx.t, ctx.addr

    def nopb(st: MachineState) -> MachineState:
        # Volatile switch: the persist round-trips to PM.
        ow = sc["ow_cpu_pm"]
        bank = channels.bank_of(addr, ctx.n_banks)
        pm_start = channels.service_start(st.pm_busy, bank, t + ow)
        ack = pm_start + sc["nvm_write"] + ow
        stats = st.stats.at[S_PERSIST_SUM].add(ack - t)
        stats = stats.at[S_PERSIST_CNT].add(1.0)
        stats = stats.at[S_PM_WRITES].add(1.0)
        return st._replace(
            clock=st.clock.at[ctx.c].set(ack),
            pm_busy=channels.reserve(st.pm_busy, bank, pm_start,
                                     sc["nvm_w_occ"]),
            stats=stats)

    def pb(st: MachineState) -> MachineState:
        return _persist_with_buffer(
            ctx, st, coalesce_enabled=False,
            drain_policy=lambda **kw: policy.drain_immediate(
                sc, kw["bank"], ctx.slot_ids, kw["wslot"], kw["t_written"],
                kw["state3"], kw["dd3"], kw["pm_busy1"]))

    def pb_rf(st: MachineState) -> MachineState:
        return _persist_with_buffer(
            ctx, st, coalesce_enabled=True,
            drain_policy=lambda **kw: policy.drain_threshold_preset(
                sc, ctx.n_banks, ctx.slot_active, kw["t_written"],
                kw["state3"], kw["tag3"], kw["lru3"], kw["dd3"],
                kw["pm_busy1"]))

    return jax.lax.switch(ctx.scheme, [nopb, pb, pb_rf], st)


# ----------------------------------------------------------------- barrier
def handle_barrier(ctx: StepCtx, st: MachineState) -> MachineState:
    # centralized barrier over all participating cores; the last arrival
    # releases everyone at its arrival time.
    last = (st.bcount + 1) >= ctx.n_live
    released = jnp.where(st.blocked, ctx.t, st.clock).at[ctx.c].set(ctx.t)
    waiting = st.clock.at[ctx.c].set(INF * 0.9)
    return st._replace(clock=jnp.where(last, released, waiting))


HANDLERS = [handle_compute, handle_dram_read, handle_dram_write,
            handle_pm_read, handle_persist, handle_barrier]
