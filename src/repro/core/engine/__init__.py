"""Composable timed PCS engine (DESIGN.md §3).

Replaces the monolithic ``_simulate`` scan with individually testable
pieces:

  * ``state``    — machine-state pytree, stats layout, config lowering
  * ``channels`` — PM bank + PBC resource model (next-free scalars)
  * ``policy``   — allocation, victim selection, drain policies; the one
                   home of the scheme/threshold constants shared with
                   the untimed oracle and the checkpoint tier
  * ``handlers`` — per-op handlers with traced-scheme ``lax.switch``
  * ``step``     — clock-merge step driver + the scan (compile counter)
  * ``macro``    — guarded macro-step mini-interpreter (homogeneous-run
                   speculation; bit-exact commit-or-abort)
  * ``fabric``   — fan-out fabric helpers (leaf partition of the hop-1
                   slot axis, spine backpressure signal)
  * ``grid``     — ``simulate_grid`` / ``simulate_cells`` batched
                   front-ends and the ``simulate`` / ``simulate_sweep``
                   compat wrappers
"""
from repro.core.engine.grid import (last_macro_abort_reasons,  # noqa: F401
                                    last_macro_hit_rate,
                                    simulate, simulate_cells,
                                    simulate_grid, simulate_sweep)
from repro.core.engine.state import SimResult  # noqa: F401
from repro.core.engine.step import compile_count  # noqa: F401

__all__ = ["SimResult", "simulate", "simulate_cells", "simulate_grid",
           "simulate_sweep", "compile_count", "last_macro_hit_rate",
           "last_macro_abort_reasons"]
