"""Pluggable PB policy layer: allocation, victim selection, drain policies.

This module is the *single home* of the persistence-policy logic that was
previously restated informally in three places — the timed scan, the
untimed oracle (``core.semantics``) and the checkpoint tier
(``persistence.manager``).  It provides:

  * the canonical scheme names / drain-threshold constants (re-exported
    from ``core.params`` so every layer reads one definition);
  * :func:`rf_drain_count` — the PB_RF threshold/preset + keep-one-free
    drain decision as a pure scalar function, used verbatim by the
    untimed oracle and mirrored sub-expression-for-sub-expression by the
    traced :func:`drain_threshold_preset`;
  * the traced policy pieces of the timed engine: PB lookup
    (:func:`pb_lookup`), Empty/victim/earliest-Drain slot selection
    (:func:`select_slot`) and the per-scheme drain policies
    (:func:`drain_immediate`, :func:`drain_threshold_preset`), which the
    persist handler dispatches with ``jax.lax.switch`` on the *traced*
    scheme scalar.

All traced functions are written against the carry arrays of
``engine.state.MachineState`` and must stay bit-compatible with the
original monolithic scan: each arithmetic expression is kept in the same
form and order.
"""
from __future__ import annotations

import jax.numpy as jnp

# Canonical scalar policy: defined once in the jax-free dependency leaf
# (core.params) so the untimed oracle and the checkpoint tier can import
# it without initializing jax; re-exported here as the policy facade.
from repro.core.params import (DEFAULT_DRAIN_PRESET,          # noqa: F401
                               DEFAULT_DRAIN_THRESHOLD, RF_EMPTY_SLACK,
                               RF_LOW_WATER_DRAINS, SCHEME_NAMES, Scheme,
                               preset_count, rf_drain_count,
                               threshold_count)
from repro.core.engine.state import DIRTY, DRAIN, EMPTY, INF


# ---------------------------------------------------------------------------
# Traced policy pieces (operate on MachineState arrays)
# ---------------------------------------------------------------------------

def lazy_free(state, dd, now):
    """Observe Drain->Empty transitions whose PM ack time has passed."""
    freed = (state == DRAIN) & (dd <= now)
    return jnp.where(freed, EMPTY, state)


def pb_lookup(tag, state, slot_active, addr):
    """Newest live entry for ``addr`` (a Dirty entry supersedes Drain).

    Returns (has_entry, idx): whether any live entry matches, and the
    index of the newest one.
    """
    match = slot_active & (tag == addr) & (state != EMPTY)
    has = jnp.any(match)
    idx = jnp.argmax(match & (state == DIRTY)) * jnp.any(
        match & (state == DIRTY)) + jnp.argmax(match) * (
        ~jnp.any(match & (state == DIRTY)))
    return has, idx


def tenant_occupancy(state, slot_active, owner, n_tenants_max: int):
    """Per-tenant live-PBE counts: ``occ[t]`` = non-Empty entries owned
    by tenant ``t`` (the quota / weighted-victim accounting base)."""
    live = (slot_active & (state != EMPTY)).astype(jnp.float64)
    return jnp.zeros((n_tenants_max,), jnp.float64).at[
        jnp.clip(owner, 0, n_tenants_max - 1)].add(live)


def select_slot(sc, state, slot_active, lru, dd, owner, tenant, occ):
    """Allocation / victim selection over the PBE array (AllocPolicy).

    Preference order of the persist handler: an Empty slot (LRU-oldest),
    else the LRU Dirty entry (victim drain), else the Drain entry whose
    PM ack lands earliest (pure wait) — refined by the traced
    :class:`~repro.core.params.AllocPolicy` lowering:

      * **quota** — a tenant at/over its quota (``occ[tenant] >=
        sc["quota"][tenant]``) may not take an Empty slot; its victim /
        wait candidates are restricted to its *own* entries, so it
        recycles its own footprint instead of growing it;
      * **weighted victim** — when no Empty slot exists and
        ``sc["victim_weighted"]`` is set, the victim search prefers
        Dirty entries of tenants at/over their share
        (``occ >= sc["share"]``), falling back to the global LRU Dirty.

    With the default policy (quota INF, weighted 0) every mask reduces
    to the pre-policy form, keeping results bit-identical.
    """
    T = occ.shape[0]
    over_quota = occ[tenant] >= sc["quota"][tenant]
    own = owner == tenant
    empty_mask = slot_active & (state == EMPTY) & ~over_quota
    any_empty = jnp.any(empty_mask)
    empty_idx = jnp.argmin(jnp.where(empty_mask, lru, INF))
    dirty_all = slot_active & (state == DIRTY)
    over_share = occ >= sc["share"]                       # (T,) bool
    hot = dirty_all & over_share[jnp.clip(owner, 0, T - 1)]
    use_hot = (sc["victim_weighted"] > 0.0) & jnp.any(hot)
    dirty_mask = jnp.where(over_quota, dirty_all & own,
                           jnp.where(use_hot, hot, dirty_all))
    any_dirty = jnp.any(dirty_mask)
    victim_idx = jnp.argmin(jnp.where(dirty_mask, lru, INF))
    drain_all = slot_active & (state == DRAIN)
    drain_mask = jnp.where(over_quota, drain_all & own, drain_all)
    earliest_idx = jnp.argmin(jnp.where(drain_mask, dd, INF))
    return any_empty, empty_idx, any_dirty, victim_idx, earliest_idx


def drain_immediate(sc, bank, slot_ids, wslot, t_written,
                    state3, dd3, pm_busy1):
    """PB scheme: drain the just-written entry at once (ack at switch).

    The channel FIFO preserves the version order of same-line drains.
    Returns (state4, dd4, pm_busy2, policy_writes).
    """
    pm_start2 = jnp.maximum(pm_busy1[bank], t_written + sc["ow_sw1_pm"])
    dd_new = pm_start2 + sc["nvm_write"] + sc["ow_sw1_pm"]
    state4 = jnp.where(slot_ids == wslot, DRAIN, state3)
    dd4 = dd3.at[wslot].set(dd_new)
    pm_busy2 = pm_busy1.at[bank].set(pm_start2 + sc["nvm_w_occ"])
    return state4, dd4, pm_busy2, jnp.asarray(1.0, jnp.float64)


def surviving_entries(state, dd, slot_active, crash_at):
    """Mask of PBEs that survive a power loss at ``crash_at``.

    A Dirty entry always survives (the PB cells are persistent).  A
    Drain entry survives iff its in-flight PM write is lost with the
    power, i.e. its ack would have landed only after the crash; an ack
    at or before the crash means the write reached PM and the entry is
    (lazily) Empty at the crash instant.
    """
    return slot_active & ((state == DIRTY) |
                          ((state == DRAIN) & (dd > crash_at)))


def recovery_burst_cost(sc, per_bank, n):
    """Drain-all burst latency over aggregated per-bank survivor counts.

    Drains sharing a PM bank serialize at the bank's write occupancy
    and overlap across banks (the same burst model as
    :func:`drain_threshold_preset`); under a switch chain the counts
    aggregate the *union* of surviving entries across every hop, all
    re-drained in one recovery burst over the hop-1 drain path (the
    conservative longest path — deeper hops are strictly closer to PM).
    Latency is the time until the last re-drain is acked back at the
    switch, zero when nothing survived.
    """
    worst = jnp.max(per_bank)
    return jnp.where(
        n > 0,
        (worst - 1.0) * sc["nvm_w_occ"] + sc["nvm_write"]
        + 2.0 * sc["ow_sw1_pm"],
        0.0)


def drain_threshold_preset(sc, n_banks, slot_active, t_written,
                           state3, tag3, lru3, dd3, pm_busy1, *,
                           owner, tenant, tight=None, defer=None):
    """PB_RF: threshold/preset drain-down over LRU Dirty entries.

    Traced twin of :func:`rf_drain_count` plus the per-bank burst
    serialization: drains sharing a PM bank are issued back-to-back at
    the bank's write occupancy, overlapping across banks.

    Under a tenant-scoped :class:`~repro.core.params.DrainPolicy`
    (``sc["drain_scope"]`` set) the drain-down sees only the issuing
    tenant's Dirty entries and compares against *its* lowered counts
    (``sc["t_threshold"]/["t_preset"]``, anchored on its quota or fair
    share) — a noisy tenant's drain-down can no longer evict a quiet
    tenant's Dirty entries.  The keep-one-free low-water heuristic keeps
    watching the *global* Empty pool (it protects the shared PI front)
    but likewise drains only in-scope entries.

    ``tight`` (a traced bool, or None to skip) is the serving-SLO
    override (``DrainPolicy.latency_target_ns``): while the issuing
    tenant's observed over-target persist fraction exceeds its
    tolerance, the drain-down runs with threshold 1 / preset 0 — drain
    every in-scope Dirty entry ASAP so the next tail persist does not
    queue behind a full PB.  A never-true ``tight`` (no target set)
    selects the untightened counts and is bit-exact with ``tight=None``.

    ``defer`` (a traced bool, or None to skip) is the fabric's
    backpressure override (``FabricTopology.bp_high``): while the
    downstream spine FIFO is congested the whole drain-down — both the
    threshold leg and the keep-one-free low-water leg — is deferred
    (``k = 0``); the Dirty entries stay put and the next persist
    re-evaluates.  A never-true ``defer`` (bp_high = INF) is bit-exact
    with ``defer=None``.  Returns (state4, dd4, pm_busy2,
    policy_writes).
    """
    B = n_banks
    scoped = sc["drain_scope"] > 0.0
    in_scope = jnp.where(scoped, owner == tenant, True)
    dirty_mask = (state3 == DIRTY) & slot_active & in_scope
    dirty_cnt = jnp.sum(dirty_mask)
    empty_cnt = jnp.sum((state3 == EMPTY) & slot_active)
    thr = jnp.where(scoped, sc["t_threshold"][tenant],
                    sc["threshold_count"])
    pre = jnp.where(scoped, sc["t_preset"][tenant], sc["preset_count"])
    if tight is not None:
        thr = jnp.where(tight, 1.0, thr)  # lint: mirror(rf-tight-thr)
        pre = jnp.where(tight, 0.0, pre)  # lint: mirror(rf-tight-pre)
    do_drain = dirty_cnt >= thr  # lint: mirror(rf-do-drain)
    k_thresh = jnp.where(do_drain, dirty_cnt - pre, 0.0)  # lint: mirror(rf-k-thresh)
    k_low = jnp.where(empty_cnt <= sc["empty_slack"],  # lint: mirror(rf-k-low)
                      jnp.minimum(sc["low_water"], dirty_cnt),
                      0.0)
    k = jnp.maximum(k_thresh, k_low)
    if defer is not None:
        k = jnp.where(defer, 0.0, k)
    key = jnp.where(dirty_mask, lru3, INF)
    rank = jnp.argsort(jnp.argsort(key)).astype(jnp.float64)
    to_drain = (rank < k) & dirty_mask
    banks = tag3 % B
    # rank among drained entries sharing a bank (serializes the burst per
    # PM bank, overlapping across banks)
    same_bank = banks[:, None] == banks[None, :]
    earlier = rank[None, :] < rank[:, None]
    rank_b = jnp.sum(
        (same_bank & earlier & to_drain[None, :]).astype(jnp.float64),
        axis=1)
    start_i = (jnp.maximum(pm_busy1[banks], t_written + sc["ow_sw1_pm"])
               + rank_b * sc["nvm_w_occ"])
    dd_j = start_i + sc["nvm_write"] + sc["ow_sw1_pm"]
    state4 = jnp.where(to_drain, DRAIN, state3)
    dd4 = jnp.where(to_drain, dd_j, dd3)
    busy_after = jnp.where(to_drain, start_i + sc["nvm_w_occ"], 0.0)
    per_bank = jnp.max(
        jnp.where(same_bank & to_drain[None, :], busy_after[None, :], 0.0),
        axis=1)
    pm_busy2 = jnp.maximum(
        pm_busy1, jnp.zeros((B,), jnp.float64).at[banks].max(per_bank))
    return state4, dd4, pm_busy2, k
