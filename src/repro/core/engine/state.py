"""Machine-state pytree, statistics layout and config lowering.

The scan carry of the timed engine is one :class:`MachineState` pytree:
per-core clocks and trace cursors, the PB tables (TAT tags, ST states,
LRU stamps, in-flight drain-ack times), the resource next-free times
(PM banks, PBC) and the statistics accumulators behind Figs. 1 and 5-8.

Every latency parameter, the live PBE bound, the drain thresholds *and
the scheme id* are traced scalars (see :func:`scalars_from_config`), so
a full {trace x config x scheme} grid lowers to a single XLA program.
Only array shapes stay static: core count, ``max_pbe``, bank count and
the scan length.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.params import PBEState, PCSConfig

INF = 1e30

# statistics vector layout
S_PERSIST_SUM = 0
S_PERSIST_CNT = 1
S_READ_SUM = 2
S_READ_CNT = 3
S_READ_HITS = 4
S_COALESCES = 5
S_PM_WRITES = 6
S_STALL_TIME = 7
S_PI_DETOURS = 8
S_DRAM_READS = 9
S_VICTIM_CNT = 10    # persists that took the no-Empty victim path
S_PBCQ_SUM = 11      # total PBC queueing wait (arrival -> service start)
S_ACKED = 12         # persists whose ack reached the core before the crash
S_DURABLE = 13       # persists whose payload survives crash + recovery
N_STATS = 14

EMPTY = int(PBEState.EMPTY)
DIRTY = int(PBEState.DIRTY)
DRAIN = int(PBEState.DRAIN)


class MachineState(NamedTuple):
    """The scan carry: the entire machine at one instant.

    ``ver``/``aver``/``pm_ver`` are the durability-tracking arrays behind
    the crash model: per-PBE held version, per-address issue counter, and
    the newest version whose PM write-ack landed *before the crash point*
    (a later ack means the in-flight write is lost with the power).
    Addresses ``>= n_track`` are not tracked (A = max(n_track, 1)).
    """

    clock: jnp.ndarray     # (C,)  f64  per-core clocks
    ptr: jnp.ndarray       # (C,)  i32  per-core trace cursors
    tag: jnp.ndarray       # (P,)  i32  TAT tags (P = max_pbe)
    state: jnp.ndarray     # (P,)  i32  ST states (Empty/Dirty/Drain)
    lru: jnp.ndarray       # (P,)  f64  LRU stamps
    dd: jnp.ndarray        # (P,)  f64  in-flight drain-ack times
    ver: jnp.ndarray       # (P,)  i32  per-entry persist version
    aver: jnp.ndarray      # (A,)  i32  per-address issued-version counter
    pm_ver: jnp.ndarray    # (A,)  i32  newest version durable at PM
    pm_busy: jnp.ndarray   # (B,)  f64  PM bank next-free times
    pbc_busy: jnp.ndarray  # ()    f64  PBC next-free time
    blocked: jnp.ndarray   # (C,)  bool blocked at barrier
    bcount: jnp.ndarray    # ()    i32  barrier arrival count
    stats: jnp.ndarray     # (N_STATS,) f64


def init_state(n_cores: int, max_pbe: int, pm_banks: int,
               n_track: int = 0) -> MachineState:
    A = max(n_track, 1)
    return MachineState(
        clock=jnp.zeros((n_cores,), jnp.float64),
        ptr=jnp.zeros((n_cores,), jnp.int32),
        tag=jnp.full((max_pbe,), -1, jnp.int32),
        state=jnp.full((max_pbe,), EMPTY, jnp.int32),
        lru=jnp.zeros((max_pbe,), jnp.float64),
        dd=jnp.zeros((max_pbe,), jnp.float64),
        ver=jnp.zeros((max_pbe,), jnp.int32),
        aver=jnp.zeros((A,), jnp.int32),
        pm_ver=jnp.zeros((A,), jnp.int32),
        pm_busy=jnp.zeros((pm_banks,), jnp.float64),
        pbc_busy=jnp.zeros((), jnp.float64),
        blocked=jnp.zeros((n_cores,), bool),
        bcount=jnp.zeros((), jnp.int32),
        stats=jnp.zeros((N_STATS,), jnp.float64),
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Aggregate metrics of one simulated run.

    The durability snapshot (``acked_persists``, ``durable_persists``,
    ``recovery_*``, ``durable_ver`` under address tracking) describes a
    power loss at ``crash_at_ns`` — or, when no crash is configured
    (``inf``), a hypothetical loss right after the last op: persists all
    acked/durable, and ``recovery_entries``/``recovery_ns`` report the
    Section V-D4 drain-all cost of the Dirty entries still buffered at
    the end of the run (zero for NoPB, which buffers nothing).
    """

    runtime_ns: float
    persist_lat_ns: float       # mean persist latency (fence round trip)
    read_lat_ns: float          # mean PM-read latency (from LLC)
    persists: int
    pm_reads: int
    read_hits: int              # reads served from the PB
    coalesces: int              # persists absorbed into a Dirty entry
    pm_writes: int              # write packets that reached the PM device
    stall_ns: float             # PBC time spent waiting for Empty entries
    pi_detours: int             # reads routed through the PI buffer
    victim_drains: int = 0      # persists that took the no-Empty victim path
    crash_at_ns: float = float("inf")
    acked_persists: int = 0     # acked at the core before the crash point
    durable_persists: int = 0   # payload survives crash + recovery
    recovery_entries: int = 0   # surviving Dirty/Drain PBEs re-drained
    recovery_ns: float = 0.0    # modeled drain-all latency of recovery
    durable_ver: "np.ndarray | None" = None  # (track_addrs,) i32 or None

    @property
    def read_hit_rate(self) -> float:
        return self.read_hits / max(self.pm_reads, 1)

    @property
    def coalesce_rate(self) -> float:
        return self.coalesces / max(self.persists, 1)

    @property
    def persisted_fraction(self) -> float:
        """Fraction of issued persists durable after crash + recovery."""
        return self.durable_persists / max(self.persists, 1)


def result_from_stats(runtime: float, stats: np.ndarray, *,
                      crash_at_ns: float = float("inf"),
                      recovery_entries: int = 0,
                      recovery_ns: float = 0.0,
                      durable_ver: "np.ndarray | None" = None) -> SimResult:
    return SimResult(
        runtime_ns=runtime,
        persist_lat_ns=float(stats[S_PERSIST_SUM] / max(stats[S_PERSIST_CNT], 1)),
        read_lat_ns=float(stats[S_READ_SUM] / max(stats[S_READ_CNT], 1)),
        persists=int(stats[S_PERSIST_CNT]),
        pm_reads=int(stats[S_READ_CNT]),
        read_hits=int(stats[S_READ_HITS]),
        coalesces=int(stats[S_COALESCES]),
        pm_writes=int(stats[S_PM_WRITES]),
        stall_ns=float(stats[S_STALL_TIME]),
        pi_detours=int(stats[S_PI_DETOURS]),
        victim_drains=int(stats[S_VICTIM_CNT]),
        crash_at_ns=crash_at_ns,
        acked_persists=int(stats[S_ACKED]),
        durable_persists=int(stats[S_DURABLE]),
        recovery_entries=int(recovery_entries),
        recovery_ns=float(recovery_ns),
        durable_ver=durable_ver,
    )


def scalars_from_config(cfg: PCSConfig) -> Dict[str, float]:
    """Lower one config to the dict of traced latency/policy scalars."""
    lat = cfg.latency
    return dict(
        n_pbe=float(cfg.n_pbe),
        threshold_count=float(cfg.threshold_count),
        preset_count=float(cfg.preset_count),
        tag_ns=lat.pb_tag_ns_for(cfg.n_pbe),
        data_ns=lat.pb_data_ns_for(cfg.n_pbe),
        pbc_proc_ns=lat.pbc_proc_ns,
        pbc_occ_ns=lat.pbc_occ_ns,
        pbc_read_ns=lat.pbc_read_ns,
        pbc_read_occ=lat.pbc_read_occ_ns,
        nvm_read=lat.nvm_read_ns,
        nvm_write=lat.nvm_write_ns,
        nvm_r_occ=lat.nvm_read_occ_ns,
        nvm_w_occ=lat.nvm_write_occ_ns,
        dram_ns=lat.dram_ns,
        fwd_margin=lat.fwd_margin_ns,
        switch_pipe=lat.switch_pipe_ns,
        ow_cpu_pm=lat.oneway_cpu_pm(cfg.n_switches),
        ow_cpu_sw1=lat.oneway_cpu_sw1() if cfg.n_switches > 0 else lat.cpu_link_ns,
        ow_sw1_pm=lat.oneway_sw1_pm(cfg.n_switches) if cfg.n_switches > 0 else 0.0,
        # power-loss instant; INF (the engine's finite infinity) = never
        crash_at=min(cfg.crash_at_ns, INF),
    )
