"""Machine-state pytree, statistics layout and config lowering.

The scan carry of the timed engine is one :class:`MachineState` pytree:
per-core clocks and trace cursors, the PB tables (TAT tags, ST states,
LRU stamps, in-flight drain-ack times), the resource next-free times
(PM banks, PBC) and the statistics accumulators behind Figs. 1 and 5-8.

Every latency parameter, the live PBE bound, the drain thresholds, the
scheme id, the tenant count *and the switch-chain depth with its
per-hop capacities* are traced scalars/vectors (see
:func:`scalars_from_config`), so a full {trace x config x scheme x
tenant-count x depth} grid lowers to a single XLA program.  Only array
shapes stay static: core count, ``max_pbe``, bank count, the scan
length, the per-tenant stats row count ``n_tenants_max`` and the
deep-hop row count ``n_deep_max`` (grid max depth minus one; 0 skips
the chain code entirely, keeping depth-1 programs byte-identical to
the pre-chain engine).

Statistics are accumulated per tenant — ``stats`` is ``(T, N_STATS)``
with ``T = n_tenants_max`` — and the global :class:`SimResult` is the
sum over tenants, bit-exact for single-tenant configs (unused rows stay
exactly zero, and ``x + 0.0 == x`` in IEEE f64).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.params import (PBEState, PCSConfig, epoch_value,
                               hop_drain_counts, preset_count, resolve_epoch,
                               tenant_drain_counts, threshold_count)

INF = 1e30

# Epoched-schedule lowering (DESIGN §7): the sc keys that gain a leading
# (E,) epoch axis when any config in the grid carries a Schedule.  The
# engine resolves them per op at its issue clock (``step.resolve_epoch_sc``)
# before any handler/policy/macro code consumes them, so every downstream
# expression — including the mirror-marked sites — sees the same shapes
# as a static grid.  Everything else in the sc dict is epoch-invariant.
EPOCH_KEYS = ("threshold_count", "preset_count", "quota", "share",
              "t_threshold", "t_preset", "deep_thr", "deep_pre",
              "lat_target", "leaf_of_t")

# statistics vector layout
S_PERSIST_SUM = 0
S_PERSIST_CNT = 1
S_READ_SUM = 2
S_READ_CNT = 3
S_READ_HITS = 4
S_COALESCES = 5
S_PM_WRITES = 6
S_STALL_TIME = 7
S_PI_DETOURS = 8
S_DRAM_READS = 9
S_VICTIM_CNT = 10    # persists that took the no-Empty victim path
S_PBCQ_SUM = 11      # total PBC queueing wait (arrival -> service start)
S_ACKED = 12         # persists whose ack reached the core before the crash
S_DURABLE = 13       # persists whose payload survives crash + recovery
S_SLO_OVER = 14      # persists whose ack latency exceeded lat_target
# Fixed-bin log-spaced per-persist ack-latency histogram: columns
# S_LAT_HIST0 .. S_LAT_HIST0+N_LAT_BINS-1 of every per-tenant stats row.
# Bin 0 is the underflow bin (lat < LAT_HIST_MIN_NS); bin k >= 1 holds
# MIN*r^(k-1) <= lat < MIN*r^k with r = LAT_HIST_RATIO; the last bin is
# open above.  sqrt(2) spacing over 28 bins spans 256 ns .. ~2.1 ms —
# sub-bin percentile resolution of ~19% latency, fine enough to place a
# saturation knee while keeping the widened scan carry cheap.
S_LAT_HIST0 = 15
N_LAT_BINS = 28
N_STATS = S_LAT_HIST0 + N_LAT_BINS

LAT_HIST_MIN_NS = 256.0
LAT_HIST_RATIO = float(np.sqrt(2.0))

# per-switch (hop) statistics row layout — ``MachineState.hop_stats`` is
# ``(Hmax, N_HOP_STATS)`` with row h = switch h+1 of the chain
H_FWD_SUM = 0        # total commit latency of packets written into this hop
H_FWD_CNT = 1        # packets committed into this hop's PB (alloc+coalesce)
H_COALESCES = 2      # arrivals absorbed into an existing Dirty entry
H_BYPASS = 3         # arrivals that found the hop full and travelled deeper
H_READ_HITS = 4      # reads served from this hop's PB (read forwarding)
N_HOP_STATS = 5

EMPTY = int(PBEState.EMPTY)
DIRTY = int(PBEState.DIRTY)
DRAIN = int(PBEState.DRAIN)


def lat_bin(lat_ns):
    """Traced histogram bin index of one persist latency.

    ``log_r(x) == 2 * log2(x)`` for ``r = sqrt(2)``, so the bin index is
    an exact cheap expression; both persist accumulation sites (the
    slot-at-a-time handler and the macro-step mini-interpreter) MUST use
    this same function so macro on/off stays bit-exact.  The ``max(lat,
    1)`` guard keeps masked macro lanes (whose latency operand can be
    arbitrary garbage, added with weight 0.0) out of ``log2(<=0)``.
    """
    x = jnp.floor(jnp.log2(jnp.maximum(lat_ns, 1.0) / LAT_HIST_MIN_NS) * 2.0)
    return jnp.clip(x.astype(jnp.int32) + 1, 0, N_LAT_BINS - 1)


def lat_hist_edges() -> np.ndarray:
    """Upper bin edges: ``edges[k]`` closes bin k (k = 0..N_LAT_BINS-2).

    Bin 0 spans (0, edges[0]); bin k spans [edges[k-1], edges[k]); the
    last bin is open above edges[-1].
    """
    return LAT_HIST_MIN_NS * LAT_HIST_RATIO ** np.arange(N_LAT_BINS - 1)


def lat_hist_percentile(hist, q: float) -> float:
    """Latency at quantile ``q`` (0..1) from one histogram row.

    Linear interpolation inside the covering bin (bin 0's lower edge is
    0; the open last bin extends one more ratio step).  NaN when the
    histogram is empty — a zero-traffic cell has *no* P99, not a 0 ns
    one (same convention as :func:`_mean`).
    """
    hist = np.asarray(hist, np.float64)
    total = float(hist.sum())
    if not total > 0:
        return float("nan")
    target = q * total
    c = np.cumsum(hist)
    b = min(int(np.searchsorted(c, target, side="left")), N_LAT_BINS - 1)
    edges = lat_hist_edges()
    lo = 0.0 if b == 0 else float(edges[b - 1])
    hi = (float(edges[b]) if b < N_LAT_BINS - 1
          else float(edges[-1] * LAT_HIST_RATIO))
    prev = float(c[b - 1]) if b > 0 else 0.0
    frac = (target - prev) / hist[b] if hist[b] > 0 else 1.0
    return lo + frac * (hi - lo)


def lat_hist_mean(hist) -> float:
    """Mean latency reconstructed from the histogram (geometric-mid
    representatives; agrees with S_PERSIST_SUM/CNT to bin resolution)."""
    hist = np.asarray(hist, np.float64)
    total = float(hist.sum())
    if not total > 0:
        return float("nan")
    edges = lat_hist_edges()
    half = np.sqrt(LAT_HIST_RATIO)
    reps = np.concatenate([
        [edges[0] / half],                       # underflow bin
        np.sqrt(edges[:-1] * edges[1:]),         # interior geometric mids
        [edges[-1] * half],                      # open last bin
    ])
    return float((hist * reps).sum() / total)


class MachineState(NamedTuple):
    """The scan carry: the entire machine at one instant.

    ``ver``/``aver``/``pm_ver`` are the durability-tracking arrays behind
    the crash model: per-PBE held version, per-address issue counter, and
    the newest version whose PM write-ack landed *before the crash point*
    (a later ack means the in-flight write is lost with the power).
    Addresses ``>= n_track`` are not tracked (A = max(n_track, 1)).

    The scan carry is packed: categorical columns (``state``/``owner``
    and their deep-hop twins) live in int8, barrier arrival counts in
    int16 — weak-typed literal comparisons and ``where`` selects keep
    the narrow dtype through every handler.  Every *time* column stays
    float64: the issue-time merge, the crash compares and the lazily
    freed drain-ack stamps all subtract nanosecond-scale quantities
    from ~1e9-scale clocks, where float32 would quantize at ~100 ns and
    break the bit-exact engine<->oracle differentials.  ``tag`` (cache
    lines up to 2^20+) and the version counters stay int32.
    """

    clock: jnp.ndarray     # (C,)  f64  per-core clocks
    ptr: jnp.ndarray       # (C,)  i32  per-core trace cursors
    tag: jnp.ndarray       # (P,)  i32  TAT tags (P = max_pbe)
    state: jnp.ndarray     # (P,)  i8   ST states (Empty/Dirty/Drain)
    lru: jnp.ndarray       # (P,)  f64  LRU stamps
    dd: jnp.ndarray        # (P,)  f64  in-flight drain-ack times
    ver: jnp.ndarray       # (P,)  i32  per-entry persist version
    owner: jnp.ndarray     # (P,)  i8   tenant that last wrote each entry
                           #            (quota occupancy, weighted victim
                           #            selection, tenant-scoped drains,
                           #            per-tenant recovery attribution)
    aver: jnp.ndarray      # (A,)  i32  per-address issued-version counter
    pm_ver: jnp.ndarray    # (A,)  i32  newest version durable at PM
    pm_busy: jnp.ndarray   # (B,)  f64  PM bank next-free times
    pbc_busy: jnp.ndarray  # ()    f64  PBC next-free time
    blocked: jnp.ndarray   # (C,)  bool blocked at barrier
    bcount: jnp.ndarray    # (T,)  i16  per-tenant barrier arrival counts
    stats: jnp.ndarray     # (T, N_STATS) f64 per-tenant accumulators
    # ---- deep-hop PB columns (the switch-level axis, D = n_deep_max) ----
    # Switch j+2 of the chain owns row j of each array; the flat columns
    # above stay the first (tenant-facing) switch, so depth-1 configs run
    # byte-identical code (D == 0 skips the chain entirely at trace time).
    dtag: jnp.ndarray      # (D, P) i32  deep-hop TAT tags
    dstate: jnp.ndarray    # (D, P) i8   deep-hop ST states
    dlru: jnp.ndarray      # (D, P) f64  deep-hop LRU stamps
    ddd: jnp.ndarray       # (D, P) f64  deep-hop in-flight forward-ack times
    dver: jnp.ndarray      # (D, P) i32  deep-hop held persist versions
    downer: jnp.ndarray    # (D, P) i8   owning tenant (recovery attribution)
    dwt: jnp.ndarray       # (D, P) f64  commit time into this hop's cells
                           #             (crash gate + read visibility)
    hpbc: jnp.ndarray      # (D,)   f64  deep-hop PBC / inter-switch channel
                           #             next-free times
    hop_stats: jnp.ndarray  # (Hmax, N_HOP_STATS) f64 per-switch telemetry
    # ---- fabric (fan-out) columns, NL = n_leaves_max when > 1 else 0 ----
    # Each leaf switch owns its own PBC front: per-leaf next-free clocks
    # replace the shared scalar ``pbc_busy`` (dead-carried) when the grid
    # holds any multi-leaf fabric.  NL == 0 skips the fabric code at
    # trace time, keeping chain-only grids byte-identical to PR 5.
    lpbc: jnp.ndarray      # (NL,)  f64  per-leaf PBC next-free times


def init_state(n_cores: int, max_pbe: int, pm_banks: int,
               n_track: int = 0, n_tenants_max: int = 1,
               n_deep_max: int = 0, n_leaves_max: int = 1) -> MachineState:
    A = max(n_track, 1)
    T = max(n_tenants_max, 1)
    D = max(n_deep_max, 0)
    NL = n_leaves_max if n_leaves_max > 1 else 0
    if T > 127:
        raise ValueError("n_tenants_max exceeds the int8 owner column")
    return MachineState(
        clock=jnp.zeros((n_cores,), jnp.float64),
        ptr=jnp.zeros((n_cores,), jnp.int32),
        tag=jnp.full((max_pbe,), -1, jnp.int32),
        state=jnp.full((max_pbe,), EMPTY, jnp.int8),
        lru=jnp.zeros((max_pbe,), jnp.float64),
        dd=jnp.zeros((max_pbe,), jnp.float64),
        ver=jnp.zeros((max_pbe,), jnp.int32),
        owner=jnp.zeros((max_pbe,), jnp.int8),
        aver=jnp.zeros((A,), jnp.int32),
        pm_ver=jnp.zeros((A,), jnp.int32),
        pm_busy=jnp.zeros((pm_banks,), jnp.float64),
        pbc_busy=jnp.zeros((), jnp.float64),
        blocked=jnp.zeros((n_cores,), bool),
        bcount=jnp.zeros((T,), jnp.int16),
        stats=jnp.zeros((T, N_STATS), jnp.float64),
        dtag=jnp.full((D, max_pbe), -1, jnp.int32),
        dstate=jnp.full((D, max_pbe), EMPTY, jnp.int8),
        dlru=jnp.zeros((D, max_pbe), jnp.float64),
        ddd=jnp.zeros((D, max_pbe), jnp.float64),
        dver=jnp.zeros((D, max_pbe), jnp.int32),
        downer=jnp.zeros((D, max_pbe), jnp.int8),
        dwt=jnp.zeros((D, max_pbe), jnp.float64),
        hpbc=jnp.zeros((D,), jnp.float64),
        hop_stats=jnp.zeros((D + 1, N_HOP_STATS), jnp.float64),
        lpbc=jnp.zeros((NL,), jnp.float64),
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Aggregate metrics of one simulated run.

    The durability snapshot (``acked_persists``, ``durable_persists``,
    ``recovery_*``, ``durable_ver`` under address tracking) describes a
    power loss at ``crash_at_ns`` — or, when no crash is configured
    (``inf``), a hypothetical loss right after the last op: persists all
    acked/durable, and ``recovery_entries``/``recovery_ns`` report the
    Section V-D4 drain-all cost of the Dirty entries still buffered at
    the end of the run (zero for NoPB, which buffers nothing).

    Multi-tenant runs additionally carry the raw per-tenant stats matrix
    (``tenant_stats``, ``(n_tenants, N_STATS)``); the scalar fields above
    are always the sum over tenants (bit-exact for ``n_tenants == 1``),
    and :meth:`tenant_results` rebuilds one :class:`SimResult` per tenant
    for fairness analysis.  Mean latencies are ``NaN`` (not ``0.0``) when
    the corresponding count is zero — e.g. a run crashed at t=0 has no
    persist latency, not an infinitely fast one.
    """

    runtime_ns: float
    persist_lat_ns: float       # mean persist latency (fence round trip)
    read_lat_ns: float          # mean PM-read latency (from LLC)
    persists: int
    pm_reads: int
    read_hits: int              # reads served from the PB
    coalesces: int              # persists absorbed into a Dirty entry
    pm_writes: int              # write packets that reached the PM device
    stall_ns: float             # PBC time spent waiting for Empty entries
    pi_detours: int             # reads routed through the PI buffer
    victim_drains: int = 0      # persists that took the no-Empty victim path
    crash_at_ns: float = float("inf")
    acked_persists: int = 0     # acked at the core before the crash point
    durable_persists: int = 0   # payload survives crash + recovery
    recovery_entries: int = 0   # surviving Dirty/Drain PBEs re-drained
    recovery_ns: float = 0.0    # modeled drain-all latency of recovery
    durable_ver: "np.ndarray | None" = None  # (track_addrs,) i32 or None
    n_tenants: int = 1
    tenant_stats: "np.ndarray | None" = None  # (n_tenants, N_STATS) f64
    # Surviving Dirty/Drain PBEs per owning tenant at the crash instant
    # (row sum == recovery_entries); recovery latency stays global (the
    # drain-all pass is one shared burst over the whole PB).
    tenant_recovery: "np.ndarray | None" = None  # (n_tenants,) i64 or None
    # ---- switch-chain telemetry (pooling topologies) -------------------
    # ``hop_stats`` row h = switch h+1 (N_HOP_STATS columns: commit
    # latency sum/count, coalesces, bypasses, read hits); ``hop_recovery``
    # = surviving PBEs per switch at the crash instant (sum over hops ==
    # recovery_entries).  ``None`` for NoPB / depth-0 runs, which have no
    # persistent hops.
    n_hops: int = 0
    hop_stats: "np.ndarray | None" = None     # (n_hops, N_HOP_STATS) f64
    hop_recovery: "np.ndarray | None" = None  # (n_hops,) i64 or None
    # ---- serving / SLO telemetry (tail-latency distribution) -----------
    # ``lat_hist`` is the fixed-bin log-spaced per-persist ack-latency
    # histogram (N_LAT_BINS columns of the stats block, summed over
    # tenants here; per-tenant rows come back via tenant_results()).
    # ``slo_violations`` counts persists over DrainPolicy.latency_target_ns
    # (0 when no target is set — nothing is ever over +inf).
    lat_hist: "np.ndarray | None" = None      # (N_LAT_BINS,) f64 or None
    slo_violations: int = 0
    # ---- fabric telemetry (fan-out topologies) -------------------------
    # Surviving hop-1 PBEs per *leaf switch* at the crash instant (the
    # per-node attribution of a fan-out recovery; the spine's survivors
    # are ``hop_recovery[1]``).  ``None`` for chains / 1-leaf fabrics —
    # so a 1-leaf fabric's SimResult is field-identical to the chain's.
    leaf_recovery: "np.ndarray | None" = None  # (n_leaves,) i64 or None

    def persist_lat_pct(self, q: float) -> float:
        """Persist ack-latency quantile from the histogram (NaN when the
        cell saw no persists or carries no histogram)."""
        if self.lat_hist is None:
            return float("nan")
        return lat_hist_percentile(self.lat_hist, q)

    @property
    def persist_lat_p50(self) -> float:
        return self.persist_lat_pct(0.50)

    @property
    def persist_lat_p95(self) -> float:
        return self.persist_lat_pct(0.95)

    @property
    def persist_lat_p99(self) -> float:
        return self.persist_lat_pct(0.99)

    @property
    def read_hit_rate(self) -> float:
        return self.read_hits / max(self.pm_reads, 1)

    @property
    def coalesce_rate(self) -> float:
        return self.coalesces / max(self.persists, 1)

    @property
    def persisted_fraction(self) -> float:
        """Fraction of issued persists durable after crash + recovery."""
        return self.durable_persists / max(self.persists, 1)

    def hop_results(self) -> "list[dict]":
        """Per-switch view of the chain: one dict per hop.

        ``fwd_lat_ns`` (mean commit latency into the hop) follows the
        PR 3 NaN convention: a hop that saw zero traffic has *no* mean
        latency, not a 0.0 ns one — figure scripts must skip NaN rows.
        """
        if self.hop_stats is None:
            return []
        recov = self.hop_recovery
        return [dict(
                    hop=h + 1,
                    fwd_lat_ns=_mean(row[H_FWD_SUM], row[H_FWD_CNT]),
                    commits=int(row[H_FWD_CNT]),
                    coalesces=int(row[H_COALESCES]),
                    bypasses=int(row[H_BYPASS]),
                    read_hits=int(row[H_READ_HITS]),
                    recovered=(int(recov[h]) if recov is not None else 0))
                for h, row in enumerate(np.asarray(self.hop_stats))]

    def tenant_results(self) -> "list[SimResult]":
        """Per-tenant view: one SimResult built from each stats row.

        ``runtime_ns`` and ``crash_at_ns`` are machine-global and shared.
        ``recovery_entries`` is attributed to the tenant *owning* each
        surviving PBE (``tenant_recovery``); the drain-all recovery
        latency stays global (one shared burst over the whole PB), so
        per-tenant ``recovery_ns`` is 0.  Each row's durable fraction is
        ``persisted_fraction`` as usual (per-tenant S_DURABLE counts).
        """
        if self.tenant_stats is None:
            return [self]
        recov = self.tenant_recovery
        return [result_from_stats(
                    self.runtime_ns, row, crash_at_ns=self.crash_at_ns,
                    recovery_entries=(int(recov[t]) if recov is not None
                                      else 0))
                for t, row in enumerate(np.asarray(self.tenant_stats))]


def _mean(total: float, count: float) -> float:
    """NaN for empty means: a cell with no persists/reads has *no* mean
    latency, not a 0.0 ns one (which plots as infinitely fast)."""
    return float(total / count) if count > 0 else float("nan")


def result_from_stats(runtime: float, stats: np.ndarray, *,
                      crash_at_ns: float = float("inf"),
                      recovery_entries: int = 0,
                      recovery_ns: float = 0.0,
                      durable_ver: "np.ndarray | None" = None,
                      n_tenants: int = 1,
                      tenant_recovery: "np.ndarray | None" = None,
                      n_hops: int = 0,
                      hop_stats: "np.ndarray | None" = None,
                      hop_recovery: "np.ndarray | None" = None,
                      n_leaves: int = 1,
                      leaf_recovery: "np.ndarray | None" = None
                      ) -> SimResult:
    """Build a SimResult from a stats vector or per-tenant stats matrix.

    ``stats`` is ``(N_STATS,)`` or ``(T, N_STATS)`` with ``T >=
    n_tenants``; rows beyond the config's tenant count are structural
    padding (shared static shape of a mixed-tenant grid) and provably
    all-zero, so the global sum over rows is bit-exact for ``T == 1``.
    """
    stats = np.asarray(stats, np.float64)
    if stats.ndim == 1:
        stats = stats[None, :]
    tot = stats.sum(axis=0)
    return SimResult(
        runtime_ns=runtime,
        persist_lat_ns=_mean(tot[S_PERSIST_SUM], tot[S_PERSIST_CNT]),
        read_lat_ns=_mean(tot[S_READ_SUM], tot[S_READ_CNT]),
        persists=int(tot[S_PERSIST_CNT]),
        pm_reads=int(tot[S_READ_CNT]),
        read_hits=int(tot[S_READ_HITS]),
        coalesces=int(tot[S_COALESCES]),
        pm_writes=int(tot[S_PM_WRITES]),
        stall_ns=float(tot[S_STALL_TIME]),
        pi_detours=int(tot[S_PI_DETOURS]),
        victim_drains=int(tot[S_VICTIM_CNT]),
        crash_at_ns=crash_at_ns,
        acked_persists=int(tot[S_ACKED]),
        durable_persists=int(tot[S_DURABLE]),
        recovery_entries=int(recovery_entries),
        recovery_ns=float(recovery_ns),
        durable_ver=durable_ver,
        n_tenants=n_tenants,
        tenant_stats=(stats[:n_tenants].copy() if n_tenants > 1 else None),
        tenant_recovery=(
            np.asarray(tenant_recovery, np.int64)[:n_tenants].copy()
            if n_tenants > 1 and tenant_recovery is not None else None),
        n_hops=n_hops,
        hop_stats=(np.asarray(hop_stats, np.float64)[:n_hops].copy()
                   if n_hops > 0 and hop_stats is not None else None),
        hop_recovery=(np.asarray(hop_recovery, np.int64)[:n_hops].copy()
                      if n_hops > 0 and hop_recovery is not None else None),
        lat_hist=tot[S_LAT_HIST0:S_LAT_HIST0 + N_LAT_BINS].copy(),
        slo_violations=int(tot[S_SLO_OVER]),
        leaf_recovery=(
            np.asarray(leaf_recovery, np.int64)[:n_leaves].copy()
            if n_leaves > 1 and leaf_recovery is not None else None),
    )


def scalars_from_config(cfg: PCSConfig,
                        n_tenants_max: int | None = None,
                        n_deep_max: int = 0,
                        n_leaves_max: int = 1,
                        n_epochs_max: int = 1
                        ) -> Dict[str, "float | np.ndarray"]:
    """Lower one config to the dict of traced latency/policy scalars.

    The :class:`~repro.core.params.PBPolicy` on the config lowers here
    exactly like ``crash_at_ns`` / ``n_tenants`` do — to traced scalars
    (victim mode, drain scope, keep-one-free knobs) and per-tenant
    traced *vectors* of static length ``n_tenants_max`` (quotas, shares,
    tenant-scoped drain counts) — so a mixed {workload x scheme x
    policy} grid stays one XLA program.  Rows past the config's own
    tenant count are padding: quota/share are INF (never over) and the
    drain counts fall back to the global values (never selected).

    Epoched schedules (DESIGN §7): when the grid-wide epoch bound
    ``n_epochs_max`` is > 1, every :data:`EPOCH_KEYS` entry gains a
    leading ``(E,)`` axis — row ``e`` is the knob resolved during epoch
    ``e`` (``params.resolve_epoch``; static knobs broadcast, schedules
    shorter than the bound hold their final value) — plus the config's
    shared ``epoch_bounds`` vector, INF-padded like ``leaf_base`` so a
    static config inside a scheduled grid never leaves epoch 0.  At the
    default bound of 1 the dict is byte-identical to the pre-schedule
    lowering (no ``epoch_bounds`` key, no epoch axes), so existing
    grids recompile nothing.
    """
    lat = cfg.latency
    pol = cfg.policy
    T = max(n_tenants_max or cfg.n_tenants, 1)
    E1 = max(n_epochs_max, 1)
    if cfg.n_epochs > E1:
        # silently clamping epochs would run a scheduled config under a
        # truncated schedule — right-shaped, quietly wrong results
        raise ValueError(
            f"config has {cfg.n_epochs} epochs but the grid's static "
            f"epoch bound is {E1} (n_epochs_max={n_epochs_max}); "
            "stack the grid with the true max epoch count")
    # per-hop chain lowering: row j describes switch j+2 (deep hops only;
    # hop 1 keeps the legacy scalars).  Rows past the config's own depth
    # lower to size 0 — structurally inactive in a mixed-depth grid.
    D1 = max(n_deep_max, 1)
    hop_pbes = cfg.hop_pbes
    if len(hop_pbes) - 1 > D1:
        # silently truncating deep rows would lower a depth-N chain as a
        # shallower one — right-shaped, quietly wrong results
        raise ValueError(
            f"config has {len(hop_pbes) - 1} deep hops but the grid's "
            f"static deep-row bound is {D1} (n_deep_max={n_deep_max}); "
            "stack the grid with the true max depth")
    deep_pbe = np.zeros((D1,), np.float64)
    # per-hop CACTI-scaled tag/data lookup latencies: a small deep hop
    # must not be billed at hop 1's capacity-scaled cost (rows past the
    # config's depth keep a finite filler; they are never selected)
    deep_tag = np.full((D1,), lat.pb_tag_ns, np.float64)
    deep_data = np.full((D1,), lat.pb_data_ns, np.float64)
    for j, n_h in enumerate(hop_pbes[1:]):
        deep_pbe[j] = float(n_h)
        deep_tag[j] = lat.pb_tag_ns_for(n_h)
        deep_data[j] = lat.pb_data_ns_for(n_h)
    # ---- fabric (fan-out) lowering -----------------------------------
    # The tree descriptor lowers to a scalar leaf count, a per-tenant
    # leaf map and the per-leaf slot-window bases.  Non-fabric configs
    # lower to the degenerate values (1 leaf, everyone on leaf 0, base
    # vector [0, INF, ...] so every slot maps to leaf 0, bp_high = INF),
    # which the leaf masks neutralize — a chain cell inside a fabric
    # grid runs the global hop-1 behaviour bit-exactly.
    NL1 = max(n_leaves_max, 1)
    fab = cfg.fabric
    if fab is not None and fab.n_leaves > NL1:
        raise ValueError(
            f"config has {fab.n_leaves} leaves but the grid's static "
            f"leaf bound is {NL1} (n_leaves_max={n_leaves_max}); "
            "stack the grid with the true max leaf count")
    leaf_base = np.full((NL1,), INF, np.float64)
    leaf_base[0] = 0.0
    bp_high = INF
    if fab is not None:
        for i, b in enumerate(fab.leaf_bases()):
            leaf_base[i] = float(b)
        if fab.bp_high is not None:
            bp_high = min(float(fab.bp_high), INF)

    def rows_at(epoch: int) -> Dict[str, "float | np.ndarray"]:
        """The epoch-dependent operand rows (every :data:`EPOCH_KEYS`
        entry), resolved during ``epoch``.  Epoch 0 of a static config
        reproduces the pre-schedule lowering bit-for-bit."""
        pol_e = resolve_epoch(pol, epoch)
        thr_cnt = float(threshold_count(cfg.n_pbe, pol_e.drain.threshold))
        pre_cnt = float(preset_count(cfg.n_pbe, pol_e.drain.preset))
        deep_thr = np.ones((D1,), np.float64)
        deep_pre = np.zeros((D1,), np.float64)
        for j, (thr_h, pre_h) in enumerate(
                hop_drain_counts(pol_e, hop_pbes)[1:]):
            deep_thr[j], deep_pre[j] = float(thr_h), float(pre_h)
        leaf_of_t = np.zeros((T,), np.float64)
        if fab is not None:
            for t, lf in enumerate(epoch_value(fab.placement, epoch)):
                leaf_of_t[t] = float(lf)
        quota = np.full((T,), INF, np.float64)
        share = np.full((T,), INF, np.float64)
        t_thr = np.full((T,), thr_cnt, np.float64)
        t_pre = np.full((T,), pre_cnt, np.float64)
        for t, (thr, pre) in enumerate(
                tenant_drain_counts(pol_e, cfg.n_pbe, cfg.n_tenants)):
            quota[t] = min(pol_e.alloc.quota_of(t), INF)
            share[t] = min(pol_e.alloc.share_of(t, cfg.n_pbe,
                                                cfg.n_tenants), INF)
            t_thr[t], t_pre[t] = float(thr), float(pre)
        lt = pol_e.drain.latency_target_ns
        return dict(
            threshold_count=thr_cnt,
            preset_count=pre_cnt,
            quota=quota,
            share=share,
            t_threshold=t_thr,
            t_preset=t_pre,
            deep_thr=deep_thr,        # (D1,) switch j+2's threshold count
            deep_pre=deep_pre,        # (D1,) switch j+2's preset count
            # None lowers to INF: no persist latency ever exceeds it,
            # the running-over counter stays 0 and the tight predicate
            # is always false — bit-exact with the default policy.
            lat_target=min(lt if lt is not None else INF, INF),
            leaf_of_t=leaf_of_t,      # (T,)   tenant t's leaf switch
        )

    ep0 = rows_at(0)
    sc = dict(
        n_pbe=float(cfg.n_pbe),
        n_tenants=float(cfg.n_tenants),
        threshold_count=ep0["threshold_count"],
        preset_count=ep0["preset_count"],
        # declarative PBPolicy lowering (scalars + per-tenant vectors)
        quota=ep0["quota"],
        share=ep0["share"],
        t_threshold=ep0["t_threshold"],
        t_preset=ep0["t_preset"],
        drain_scope=1.0 if pol.drain.per_tenant else 0.0,
        victim_weighted=1.0 if pol.alloc.victim == "weighted" else 0.0,
        low_water=float(pol.drain.low_water_drains),
        empty_slack=float(pol.drain.empty_slack),
        tag_ns=lat.pb_tag_ns_for(cfg.n_pbe),
        data_ns=lat.pb_data_ns_for(cfg.n_pbe),
        pbc_proc_ns=lat.pbc_proc_ns,
        pbc_occ_ns=lat.pbc_occ_ns,
        pbc_read_ns=lat.pbc_read_ns,
        pbc_read_occ=lat.pbc_read_occ_ns,
        nvm_read=lat.nvm_read_ns,
        nvm_write=lat.nvm_write_ns,
        nvm_r_occ=lat.nvm_read_occ_ns,
        nvm_w_occ=lat.nvm_write_occ_ns,
        dram_ns=lat.dram_ns,
        fwd_margin=lat.fwd_margin_ns,
        switch_pipe=lat.switch_pipe_ns,
        ow_cpu_pm=lat.oneway_cpu_pm(cfg.n_switches),
        # the path helpers are total in the depth (0 included), so no
        # special-casing: at depth 0 (NOPB direct attach — PCSConfig
        # rejects a PB with no switch to live in) the "first hop" is the
        # CPU link and the drain path is 0, keeping the never-selected
        # PB branch of the vmapped lax.switch finite.
        ow_cpu_sw1=lat.oneway_cpu_sw1(cfg.n_switches),
        ow_sw1_pm=lat.oneway_sw1_pm(cfg.n_switches),
        # ---- switch-chain lowering (per-switch persistent buffers) ----
        n_switches=float(cfg.n_switches),
        hop_ns=lat.hop_ns(),
        link_ns=lat.link_ns,
        deep_pbe=deep_pbe,        # (D1,) switch j+2's PBE capacity
        deep_thr=ep0["deep_thr"],
        deep_pre=ep0["deep_pre"],
        deep_tag=deep_tag,        # (D1,) switch j+2's tag lookup latency
        deep_data=deep_data,      # (D1,) switch j+2's data access latency
        # ---- fabric lowering (fan-out trees over the chain) -----------
        n_leaves=float(fab.n_leaves) if fab is not None else 1.0,
        leaf_of_t=ep0["leaf_of_t"],
        leaf_base=leaf_base,      # (NL1,) first hop-1 slot of each leaf
        bp_high=bp_high,          # spine Dirty occupancy that defers
                                  # leaf drain-down (INF = never)
        # ---- serving-SLO drain tightening (DrainPolicy.latency_target_ns)
        lat_target=ep0["lat_target"],
        lat_tol=float(pol.drain.latency_tol),
        # power-loss instant; INF (the engine's finite infinity) = never
        crash_at=min(cfg.crash_at_ns, INF),
    )
    if E1 == 1:
        # static grid: byte-identical to the pre-schedule lowering — no
        # epoch axes, no epoch_bounds operand, nothing recompiles
        return sc
    # ---- epoched-schedule lowering (DESIGN §7) -----------------------
    # Every EPOCH_KEYS entry gains a leading (E,) axis; the config's
    # shared boundary vector is INF-padded to the grid bound, so a
    # static (or shorter-schedule) config can never be selected past
    # its real epochs — INF <= t_issue is false for every finite clock.
    rows = [ep0] + [rows_at(e) for e in range(1, E1)]
    for k in EPOCH_KEYS:
        sc[k] = np.stack([np.asarray(r[k], np.float64) for r in rows])
    eb = np.full((E1 - 1,), INF, np.float64)
    for i, b in enumerate(cfg.epoch_boundaries):
        eb[i] = min(float(b), INF)
    sc.update(
        epoch_bounds=eb,          # (E-1,) shared epoch-boundary vector
    )
    return sc
