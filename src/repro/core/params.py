"""Parameters for the Persistent CXL Switch (PCS) model.

Latency numbers follow the paper's experimental setup (Table I) where the
paper gives them directly (NVM 100ns read / 200ns write, PB tag/data access
from CACTI at 22nm, 4-stage switch pipeline with the Pond latency profile)
and are otherwise calibrated so the *composition* matches the paper's cited
envelope: local DRAM ~85ns, CXL-attached memory +170..400ns, Fig-1 persist
ratio ~2.5x for a single switch once fence serialization and PM queueing are
included.

Everything is expressed in nanoseconds as float64.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Optional, Tuple


class Scheme(enum.IntEnum):
    """Persistence scheme evaluated in the paper (Section VI).

    The integer values are load-bearing: the timed engine dispatches its
    persist/read handlers with ``jax.lax.switch`` on a *traced* scheme
    scalar carrying exactly these values (see ``core.engine.handlers``).
    """

    NOPB = 0   # volatile switch: every persist round-trips to PM
    PB = 1     # persistent buffer, drain-immediately (ack at switch)
    PB_RF = 2  # persistent buffer + read forwarding / write coalescing


# Canonical scalar drain policy (paper Section V-D1).  This module is the
# dependency leaf (no jax), so the untimed oracle and the checkpoint tier
# read the shared policy from here; ``core.engine.policy`` re-exports it
# next to the traced twin used by the timed engine.
DEFAULT_DRAIN_THRESHOLD = 0.8  # start draining above this fill fraction
DEFAULT_DRAIN_PRESET = 0.6     # drain down to this fill fraction

# Scheme <-> wire-name mapping shared with the checkpoint tier / CLIs.
SCHEME_NAMES = {s: s.name.lower() for s in Scheme}


def threshold_count(n_pbe: "int | float",
                    threshold: float = DEFAULT_DRAIN_THRESHOLD) -> int:
    """Entry count at which the PB_RF drain-down engages.

    ``n_pbe`` may be fractional: a tenant-scoped policy anchors the
    fraction on the tenant's quota or its fair share ``n_pbe / T``.
    """
    return max(1, int(math.ceil(threshold * n_pbe)))


def preset_count(n_pbe: "int | float",
                 preset: float = DEFAULT_DRAIN_PRESET) -> int:
    """Entry count the PB_RF drain-down drains down to."""
    return max(0, int(math.floor(preset * n_pbe)))


# PB_RF keep-one-free heuristic: when the Empty pool is down to
# RF_EMPTY_SLACK entries, drain up to RF_LOW_WATER_DRAINS LRU Dirty
# entries pre-emptively so the PI front cannot cascade into head-of-line
# victim stalls.
RF_EMPTY_SLACK = 1
RF_LOW_WATER_DRAINS = 2

# Macro-stepping window bound (engine.macro): the trace-time pre-pass
# (``core.traces.plan_runs``) caps eligible homogeneous runs at this many
# ops, and the engine's guarded macro-step unrolls exactly this many
# iterations.  The grid stacker pads every trace row by MACRO_KMAX extra
# slots so the engine's dynamic window slice never reads out of bounds.
MACRO_KMAX = 8


def rf_drain_count(dirty: int, empty: int, threshold: int, preset: int,
                   low_water: int = RF_LOW_WATER_DRAINS,
                   empty_slack: int = RF_EMPTY_SLACK) -> int:
    """How many LRU Dirty entries the PB_RF policy drains right now.

    Pure-scalar twin of ``engine.policy.drain_threshold_preset``'s ``k``
    (same sub-expressions, Python ints instead of traced f64).  The
    untimed oracle calls this directly; the engine-vs-oracle
    cross-validation test (tests/test_engine_oracle.py) is the drift
    guard between the two forms.  Under a tenant-scoped
    :class:`DrainPolicy` the caller passes the *tenant's* Dirty count
    and the *global* Empty count (the keep-one-free heuristic protects
    the shared PI front, but may only drain the tenant's own entries).
    """
    k_thresh = dirty - preset if dirty >= threshold else 0
    k_low = min(low_water, dirty) if empty <= empty_slack else 0
    return max(k_thresh, k_low)


# ---------------------------------------------------------------------------
# Epoched schedules (DESIGN.md §7)
# ---------------------------------------------------------------------------
# A production pool serves *shifting* load: tenants heat up, leaves
# saturate, and a quota/placement chosen at t=0 leaves tail latency on
# the table.  ``Schedule`` makes a sweepable knob *piecewise-constant in
# time*: ``values[e]`` is active during epoch ``e``, and the active
# epoch at time ``t`` is ``#{b in boundaries_ns : b <= t}`` — resolved
# from each op's issue clock in the timed engine (crash-style gating,
# ``engine.step``) and from the replay clock in the untimed oracle
# (``PersistentBuffer.epoch_at``).  Every scheduled knob of one config
# must share ONE boundary vector (the engine lowers a single epoch
# axis); ``PCSConfig.epoch_boundaries`` enforces it.

@dataclasses.dataclass(frozen=True)
class Schedule:
    """Piecewise-constant time schedule for a sweepable config knob.

    ``len(values) == len(boundaries_ns) + 1``: ``values[0]`` is active
    from t=0 until ``boundaries_ns[0]``, ``values[e]`` from
    ``boundaries_ns[e-1]`` (inclusive) until ``boundaries_ns[e]``.
    Accepted by ``DrainPolicy.threshold`` / ``preset`` /
    ``latency_target_ns``, ``AllocPolicy.tenant_quota`` and
    ``FabricTopology.placement``; lowers to ``(E,)`` / ``(E, T)``
    traced operand rows plus one shared ``epoch_bounds`` vector
    (``engine.state.scalars_from_config``), so a mixed
    {static x scheduled} grid stays ONE XLA program and a single-epoch
    schedule is bit-identical to the plain value.
    """

    boundaries_ns: Tuple[float, ...]
    values: Tuple[object, ...]

    def __post_init__(self) -> None:
        b = tuple(float(x) for x in self.boundaries_ns)
        v = tuple(self.values)
        if len(v) != len(b) + 1:
            raise ValueError(
                f"Schedule needs exactly one value per epoch: "
                f"{len(b)} boundaries define {len(b) + 1} epochs, "
                f"got {len(v)} values")
        if any(not math.isfinite(x) or x <= 0.0 for x in b):
            raise ValueError(
                f"Schedule boundaries must be positive finite ns; got {b}")
        if any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"Schedule boundaries must be strictly increasing; got {b}")
        object.__setattr__(self, "boundaries_ns", b)
        object.__setattr__(self, "values", v)

    @property
    def n_epochs(self) -> int:
        return len(self.values)

    def epoch_of(self, t_ns: float) -> int:
        """Active epoch at ``t_ns`` (scalar twin of the engine's
        ``jnp.sum(epoch_bounds <= t_issue)`` gate)."""
        return epoch_index(self.boundaries_ns, t_ns)

    def value_at(self, t_ns: float):
        return self.values[self.epoch_of(t_ns)]


def epoch_index(boundaries: Tuple[float, ...], x: float) -> int:
    """Active epoch at position ``x``: ``#{b : b <= x}``.

    Single home of the boundary comparison (``<=``, not ``<``) — the
    engine's traced gate, the oracle's replay clock and the checkpoint
    tier's persist-index schedule all use this rule, so the layers
    cannot drift on whether a boundary instant belongs to the new epoch
    (it does, exactly like ``crash_at`` gating).
    """
    return sum(1 for b in boundaries if b <= x)


def epoch_value(v, epoch: int):
    """Value of knob ``v`` during ``epoch``; plain values pass through.

    Epochs past the schedule's last value clamp to it (a config with
    fewer epochs than the grid-wide bound holds its final value).
    """
    if isinstance(v, Schedule):
        return v.values[min(int(epoch), len(v.values) - 1)]
    return v


def n_epochs_of(*knobs) -> int:
    """Epoch count implied by the scheduled knobs (1 = all static)."""
    return max((v.n_epochs for v in knobs if isinstance(v, Schedule)),
               default=1)


def shared_boundaries(*knobs) -> Tuple[float, ...]:
    """The ONE epoch-boundary vector shared by every scheduled knob.

    Raises when two schedules disagree — the engine lowers a single
    epoch axis per config, so every ``Schedule`` in one ``PCSConfig``
    must carry identical ``boundaries_ns``.  Returns ``()`` when
    nothing is scheduled.
    """
    bounds = None
    for v in knobs:
        if not isinstance(v, Schedule):
            continue
        if bounds is None:
            bounds = v.boundaries_ns
        elif v.boundaries_ns != bounds:
            raise ValueError(
                f"scheduled knobs disagree on epoch boundaries: "
                f"{v.boundaries_ns} vs {bounds}; every Schedule in one "
                "config must share one boundary vector (the engine "
                "lowers a single shared epoch axis)")
    return bounds if bounds is not None else ()


# ---------------------------------------------------------------------------
# Declarative persistence-policy API (QoS / drain policy, ROADMAP fairness)
# ---------------------------------------------------------------------------
# ``PBPolicy`` replaces the two global floats that used to live on
# ``PCSConfig`` plus the constants baked into this module: every knob of
# the PB's drain-down and allocation behaviour is a field of a frozen
# dataclass, and every field lowers to a traced scalar or a per-tenant
# traced vector (``engine.state.scalars_from_config``) exactly like
# ``crash_at_ns`` and ``n_tenants`` do — so a {workload x scheme x
# policy} sweep stays ONE XLA program.  The untimed oracle
# (``core.semantics``) and the checkpoint tier (``persistence.manager``)
# consume the *same* policy objects through their pure-scalar helpers.

@dataclasses.dataclass(frozen=True)
class DrainPolicy:
    """PB_RF drain-down policy (paper Section V-D1) as data.

    ``threshold`` / ``preset`` are fill fractions; ``per_tenant=True``
    scopes the drain-down to the issuing tenant: its Dirty count is
    compared against *its own* threshold (anchored on its quota, or its
    fair share ``n_pbe / T`` when no quota is set) and only *its own*
    LRU Dirty entries are drained — a noisy tenant's drain-down can no
    longer evict a quiet tenant's Dirty entries.  ``low_water_drains`` /
    ``empty_slack`` are the keep-one-free heuristic knobs that used to
    be module constants (``RF_LOW_WATER_DRAINS`` / ``RF_EMPTY_SLACK``).

    ``latency_target_ns`` is the serving-SLO closing of the loop: when
    set, each tenant tracks the running fraction of its persists whose
    ack latency exceeded the target, and while that fraction exceeds
    ``latency_tol`` the tenant's drain-down runs *tight* — threshold 1,
    preset 0 (drain everything ASAP), so a backed-up PB empties instead
    of queueing the next tail persist behind a drain burst.  The running
    fraction includes the persist being decided (a first persist over
    target immediately tightens).  Lowers to two traced scalars
    (``lat_target`` / ``lat_tol``); ``None`` lowers to the engine's
    finite infinity and is bit-exact with the default policy.
    """

    threshold: float = DEFAULT_DRAIN_THRESHOLD
    preset: float = DEFAULT_DRAIN_PRESET
    per_tenant: bool = False
    low_water_drains: int = RF_LOW_WATER_DRAINS
    empty_slack: int = RF_EMPTY_SLACK
    latency_target_ns: Optional[float] = None
    latency_tol: float = 0.05

    def __post_init__(self) -> None:
        # ``threshold`` / ``preset`` / ``latency_target_ns`` accept a
        # :class:`Schedule` (DESIGN §7): validation then runs per epoch
        # with the same rules a plain value obeys.
        for e in range(n_epochs_of(self.threshold, self.preset)):
            thr = epoch_value(self.threshold, e)
            pre = epoch_value(self.preset, e)
            if not (0.0 < pre <= thr <= 1.0):
                raise ValueError("require 0 < preset <= threshold <= 1")
        if self.low_water_drains < 0 or self.empty_slack < 0:
            raise ValueError("low_water_drains / empty_slack must be >= 0")
        for e in range(n_epochs_of(self.latency_target_ns)):
            lt = epoch_value(self.latency_target_ns, e)
            if lt is not None and not lt > 0:
                raise ValueError("latency_target_ns must be > 0 (or None)")
        if not 0.0 <= self.latency_tol < 1.0:
            raise ValueError("latency_tol must be in [0, 1)")


@dataclasses.dataclass(frozen=True)
class AllocPolicy:
    """PBE allocation / victim-selection policy.

    ``tenant_quota`` caps each tenant's live (Dirty+Drain) PBE
    occupancy: a tenant at its quota may not take an Empty slot — it
    must victim-drain (and reuse) one of its *own* LRU Dirty entries,
    or wait for its own earliest in-flight drain.  Write coalescing is
    exempt (it reuses an existing entry; a cross-tenant coalesce
    takeover can therefore push a tenant transiently over quota — the
    next allocation self-corrects).  ``victim="weighted"`` makes the
    shared no-Empty victim path prefer the LRU Dirty entry of a tenant
    at/over its share (its quota, or ``n_pbe / T`` without quotas),
    falling back to the global LRU Dirty entry.
    """

    victim: str = "lru"                              # "lru" | "weighted"
    tenant_quota: Optional[Tuple[int, ...]] = None   # live-PBE cap / tenant

    def __post_init__(self) -> None:
        if self.victim not in ("lru", "weighted"):
            raise ValueError(f"unknown victim policy {self.victim!r}; "
                             "have 'lru' | 'weighted'")
        if isinstance(self.tenant_quota, Schedule):
            # epoched quota (DESIGN §7): coerce/validate every epoch's
            # tuple with the same rules a plain quota obeys (``None``
            # epochs = uncapped); consumers resolve via
            # ``resolve_epoch`` before calling quota_of / share_of
            sch = self.tenant_quota
            vals = []
            for q0 in sch.values:
                if q0 is None:
                    vals.append(None)
                    continue
                q = tuple(int(x) for x in q0)
                if not q or any(x < 1 for x in q):
                    raise ValueError("tenant_quota entries must be >= 1")
                vals.append(q)
            object.__setattr__(self, "tenant_quota",
                               dataclasses.replace(sch, values=tuple(vals)))
        elif self.tenant_quota is not None:
            q = tuple(int(x) for x in self.tenant_quota)
            if not q or any(x < 1 for x in q):
                raise ValueError("tenant_quota entries must be >= 1")
            object.__setattr__(self, "tenant_quota", q)

    def quota_of(self, tenant: int) -> float:
        """Occupancy cap for ``tenant`` (``inf`` = unlimited).

        Requires an epoch-resolved policy (``resolve_epoch``) when the
        quota is scheduled — a ``Schedule`` is not subscriptable.
        """
        if self.tenant_quota is None:
            return math.inf
        return float(self.tenant_quota[tenant])

    def share_of(self, tenant: int, n_pbe: int, n_tenants: int) -> float:
        """Over-share boundary of the weighted victim policy."""
        if self.tenant_quota is not None:
            return float(self.tenant_quota[tenant])
        return n_pbe / max(n_tenants, 1)


@dataclasses.dataclass(frozen=True)
class PBPolicy:
    """The full persistence policy: drain-down x allocation.

    Composes with :class:`PCSConfig` (``PCSConfig(policy=...)``); the
    legacy ``drain_threshold`` / ``drain_preset`` floats forward into a
    default ``PBPolicy`` (compat shim, see DESIGN.md "Policy API").
    """

    drain: DrainPolicy = dataclasses.field(default_factory=DrainPolicy)
    alloc: AllocPolicy = dataclasses.field(default_factory=AllocPolicy)

    def validate_for(self, n_pbe: int, n_tenants: int) -> None:
        """Config-dependent validation, called by PCSConfig.__post_init__.

        A scheduled quota validates every epoch's tuple — each epoch
        must be a quota the shared buffer could honour on its own.
        """
        for e in range(n_epochs_of(self.alloc.tenant_quota)):
            q = epoch_value(self.alloc.tenant_quota, e)
            if q is None:
                continue
            if len(q) != n_tenants:
                raise ValueError(
                    f"tenant_quota has {len(q)} entries for "
                    f"n_tenants={n_tenants}; need exactly one per tenant")
            if sum(q) > n_pbe:
                raise ValueError(
                    f"tenant quotas sum to {sum(q)} > n_pbe={n_pbe}: the "
                    "shared buffer cannot honour them")


def resolve_epoch(policy: PBPolicy, epoch: int) -> PBPolicy:
    """Epoch-resolved twin of ``policy``: every scheduled field collapsed
    to its value during ``epoch`` (plain fields pass through untouched).

    Single home of the policy epoch-resolution rule: the engine lowering
    (``engine.state.scalars_from_config``) resolves each epoch's operand
    row through it, the untimed oracle (``semantics.PersistentBuffer
    .set_epoch``) re-derives its cached policy values through it, and
    the checkpoint tier (``persistence.manager``) resolves its
    persist-indexed quota steps through it — so the three layers cannot
    drift on what a schedule means.  Re-runs the dataclass validation,
    so every resolved epoch is a policy that would have been legal
    standalone.
    """
    d, a = policy.drain, policy.alloc
    return PBPolicy(
        drain=DrainPolicy(
            threshold=epoch_value(d.threshold, epoch),
            preset=epoch_value(d.preset, epoch),
            per_tenant=d.per_tenant,
            low_water_drains=d.low_water_drains,
            empty_slack=d.empty_slack,
            latency_target_ns=epoch_value(d.latency_target_ns, epoch),
            latency_tol=d.latency_tol),
        alloc=AllocPolicy(
            victim=a.victim,
            tenant_quota=epoch_value(a.tenant_quota, epoch)))


def hop_drain_counts(policy: PBPolicy,
                     hop_pbes: Tuple[int, ...]) -> List[Tuple[int, int]]:
    """Per-hop (threshold_count, preset_count) of a chained PB_RF drain.

    Hop ``h``'s drain-down anchors on *its own* PBE capacity with the
    policy's global fill fractions.  Single home of the per-hop count
    rule: the engine lowering (``engine.state.scalars_from_config``) and
    the untimed oracle (``semantics.PersistentBuffer``) both call it, so
    the traced and scalar forms cannot drift.  Deep hops (h >= 2) run
    the pure threshold/preset rule — the keep-one-free low-water
    heuristic stays at hop 1, where it protects the tenant-facing PI
    front.
    """
    return [(threshold_count(n, policy.drain.threshold),
             preset_count(n, policy.drain.preset)) for n in hop_pbes]


def tenant_drain_counts(policy: PBPolicy, n_pbe: int,
                        n_tenants: int) -> List[Tuple[int, int]]:
    """Per-tenant (threshold_count, preset_count) of a tenant-scoped drain.

    Tenant ``t``'s drain-down anchors on its quota when one is set, else
    on its fair share ``n_pbe / T``.  This is the single home of the
    per-tenant count rule: the engine lowering
    (``engine.state.scalars_from_config``) and the untimed oracle
    (``semantics.PersistentBuffer``) both call it, so the traced and
    scalar forms cannot drift.
    """
    out = []
    for t in range(n_tenants):
        base = policy.alloc.quota_of(t)
        if not math.isfinite(base):
            base = n_pbe / max(n_tenants, 1)
        out.append((threshold_count(base, policy.drain.threshold),
                    preset_count(base, policy.drain.preset)))
    return out


@dataclasses.dataclass(frozen=True)
class FabricTopology:
    """Two-level fan-out fabric: leaf switches sharing one spine.

    Real CXL pooling deployments are trees, not chains: many leaf
    switches (each the ack point for its own hosts) fan into a shared
    spine switch in front of the PM banks.  The descriptor is frozen
    data, and — like :class:`PBPolicy` and ``crash_at_ns`` — lowers to
    traced scalars/vectors (``engine.state.scalars_from_config``):
    ``n_leaves`` + the per-tenant ``placement`` map + the per-leaf slot
    partition + ``bp_high`` all reach the compiled program as operands,
    so a {workload x scheme x topology x placement} sweep stays ONE XLA
    program; only the grid-wide ``n_leaves`` maximum is a static shape.

    ``leaf_pbe[i]`` is leaf ``i``'s PBE capacity; the leaves partition
    one hop-1 slot axis (leaf ``i`` owns the contiguous slot window
    starting at ``leaf_bases()[i]``), so the 1-leaf fabric is *exactly*
    the linear chain.  ``spine_pbe`` is the spine switch's PB capacity
    (hop 2 of the lowered chain).  ``placement[t]`` is tenant ``t``'s
    leaf: a tenant's persists allocate/coalesce/victim/drain only
    within its own leaf's slot window, and drains from all leaves merge
    into the spine's occupancy-serialized FIFO (fan-in contention).

    ``bp_high`` is the backpressure-aware drain-scheduling knob: when
    the spine PB's live (Dirty) occupancy is at/above ``bp_high``
    entries, every leaf's PB_RF threshold/low-water drain-down is
    *deferred* (``spine_defer``) — leaves hold their Dirty entries
    instead of piling more fan-in onto a congested spine.  Victim
    drains (forward progress) and the PB scheme's drain-immediate are
    exempt.  ``None`` lowers to the engine's finite infinity (never
    defer) and requires nothing; a finite ``bp_high`` requires
    ``n_leaves >= 2`` so a 1-leaf fabric is bit-identical to the chain
    in every grid composition.
    """

    n_leaves: int = 1
    leaf_pbe: Tuple[int, ...] = (16,)
    spine_pbe: int = 16
    placement: Tuple[int, ...] = (0,)   # tenant -> leaf
    bp_high: Optional[float] = None     # spine Dirty occupancy, entries

    def __post_init__(self) -> None:
        if self.n_leaves < 1:
            raise ValueError("n_leaves must be >= 1")
        q = tuple(int(x) for x in self.leaf_pbe)
        if len(q) != self.n_leaves:
            raise ValueError(
                f"leaf_pbe has {len(q)} entries for "
                f"n_leaves={self.n_leaves}; need one per leaf")
        if any(x < 1 for x in q):
            raise ValueError("leaf_pbe entries must be >= 1")
        object.__setattr__(self, "leaf_pbe", q)
        if self.spine_pbe < 1:
            raise ValueError("spine_pbe must be >= 1")
        if isinstance(self.placement, Schedule):
            # epoched placement (DESIGN §7) = mid-run tenant migration:
            # each epoch's map validates like a plain placement, and
            # every epoch must place every tenant on a real leaf
            sch = self.placement
            vals = []
            for p0 in sch.values:
                p = tuple(int(x) for x in p0)
                if not p:
                    raise ValueError(
                        "placement needs at least one tenant entry")
                if any(not 0 <= x < self.n_leaves for x in p):
                    raise ValueError(
                        f"placement entries must be leaf ids in [0, "
                        f"{self.n_leaves}); got {p}")
                vals.append(p)
            object.__setattr__(self, "placement",
                               dataclasses.replace(sch, values=tuple(vals)))
        else:
            p = tuple(int(x) for x in self.placement)
            if not p:
                raise ValueError("placement needs at least one tenant entry")
            if any(not 0 <= x < self.n_leaves for x in p):
                raise ValueError(
                    f"placement entries must be leaf ids in [0, "
                    f"{self.n_leaves}); got {p}")
            object.__setattr__(self, "placement", p)
        if self.bp_high is not None:
            if not self.bp_high > 0:
                raise ValueError("bp_high must be > 0 (or None)")
            if self.n_leaves < 2:
                # a 1-leaf fabric must be bit-identical to the linear
                # chain regardless of what else shares the grid
                raise ValueError(
                    "bp_high requires n_leaves >= 2: backpressure on a "
                    "1-leaf fabric would diverge from the chain path")

    def leaf_bases(self) -> Tuple[int, ...]:
        """First hop-1 slot of each leaf's window (cumulative offsets)."""
        bases, acc = [], 0
        for n in self.leaf_pbe:
            bases.append(acc)
            acc += n
        return tuple(bases)


def spine_defer(spine_live, bp_high):
    """Backpressure contract: leaf threshold/low-water drain-down defers
    while the spine PB's live (Dirty) occupancy has reached ``bp_high``.

    Single home of the comparison — the timed engine calls it with
    traced f64 operands, the untimed oracle with Python scalars — so
    the two layers cannot drift on the boundary (``>=``, not ``>``).
    """
    return spine_live >= bp_high


class PBEState(enum.IntEnum):
    """Persistent Buffer Entry states (Section V-A)."""

    EMPTY = 0  # drained & acknowledged by PM; slot reusable
    DIRTY = 1  # latest & only copy lives in the PB
    DRAIN = 2  # a copy is in flight to PM; entry pinned until PM ack


class Op(enum.IntEnum):
    """Trace operation kinds consumed by the simulator."""

    COMPUTE = 0     # advance core clock by `gap` ns (no memory traffic)
    DRAM_READ = 1   # volatile read (blocking, local DRAM latency)
    DRAM_WRITE = 2  # volatile write (posted, ~free)
    PM_READ = 3     # load of persistent heap data (blocking, LLC miss)
    PERSIST = 4     # clflush+mfence pair: blocking store to PM
    BARRIER = 5     # synchronize all cores (Splash-4 phase barriers)


@dataclasses.dataclass(frozen=True)
class LatencyProfile:
    """One-way / device latencies (ns). See module docstring for calibration."""

    cpu_link_ns: float = 42.5     # CPU LLC <-> local controller / root port
    link_ns: float = 50.0         # one CXL link segment, one way
    switch_pipe_ns: float = 50.0  # 4-stage switch pipeline traversal
    nvm_read_ns: float = 100.0    # paper Table I
    nvm_write_ns: float = 200.0   # paper Table I
    # Channel occupancy per request (device-internal pipelining lets a PM
    # device sustain more than 1/latency requests per second; latency above
    # is what the *requester* observes, occupancy is when the channel can
    # accept the next request).
    nvm_read_occ_ns: float = 50.0
    nvm_write_occ_ns: float = 60.0
    dram_ns: float = 85.0         # volatile round trip (local DDR4-2400)
    pb_tag_ns: float = 0.388      # CACTI 22nm, 16 entries (paper Table I)
    pb_data_ns: float = 0.785     # CACTI 22nm, 16 entries (paper Table I)
    pbc_proc_ns: float = 60.0     # PBC packet handling + 64B commit into
                                  # persistent cells (the 0.785ns CACTI data
                                  # latency is the SRAM-style array access;
                                  # persisting the block costs tens of ns)
    pbc_occ_ns: float = 20.0      # PBC issue interval (pipelined FIFO
                                  # service of the PI front)
    pbc_read_ns: float = 12.0     # PBC service latency for a READ (header
                                  # decode + tag + data array read -- no
                                  # persistent-cell commit)
    pbc_read_occ_ns: float = 12.0
    # Staleness window between PBCS classification and PBC processing: a
    # Drain entry whose PM ack lands within this window of the PBC service
    # time is treated as already drained-and-replaced (Section V-D3), so
    # the read is forwarded to PM through the PO buffer.
    fwd_margin_ns: float = 150.0

    def pb_tag_ns_for(self, n_pbe: int) -> float:
        """CACTI-style growth of tag access latency with entry count.

        The paper recomputes tag latency per PBE count with CACTI; published
        CACTI fits grow ~ sqrt(capacity) for small fully-associative arrays.
        Anchored at the paper's 16-entry / 0.388 ns point.
        """
        return self.pb_tag_ns * math.sqrt(max(n_pbe, 1) / 16.0)

    def pb_data_ns_for(self, n_pbe: int) -> float:
        return self.pb_data_ns * math.sqrt(max(n_pbe, 1) / 16.0)

    # -- path helpers (chain of `n_sw` switches between CPU and PM) --------
    # All three are total functions of the depth, well-defined at n_sw == 0
    # (direct-attached PM): the first "hop" degenerates to the CPU link and
    # the drain path to nothing, so the composition identity
    # ``oneway_cpu_pm(n) == oneway_cpu_sw1(n) + oneway_sw1_pm(n)`` holds for
    # EVERY n >= 0 (tests/test_latency_profile.py pins it) and the engine
    # lowering needs no depth special-casing.
    def oneway_cpu_pm(self, n_sw: int) -> float:
        """CPU -> PM through a chain of n_sw switches (n_sw may be 0)."""
        if n_sw == 0:
            return self.cpu_link_ns
        return (n_sw + 1) * self.link_ns + n_sw * self.switch_pipe_ns

    def oneway_cpu_sw1(self, n_sw: int = 1) -> float:
        """CPU -> through the first switch (where the PB lives).

        At depth 0 there is no switch: the "first hop" is the direct CPU
        link to the PM controller, and :meth:`oneway_sw1_pm` is 0.
        """
        if n_sw == 0:
            return self.cpu_link_ns
        return self.link_ns + self.switch_pipe_ns

    def oneway_sw1_pm(self, n_sw: int) -> float:
        """First switch -> PM (the single-PB drain path); 0 at depth 0."""
        if n_sw == 0:
            return 0.0
        return n_sw * self.link_ns + (n_sw - 1) * self.switch_pipe_ns

    def hop_ns(self) -> float:
        """One inter-switch segment, one way (switch h -> switch h+1).

        The chained-PB forward path: a drain from hop h's PB travels one
        link plus one switch-pipeline traversal to reach hop h+1's PBC.
        ``oneway_sw1_pm(n) == (n-1) * hop_ns() + link_ns`` for n >= 1 —
        the chain decomposition of the drain path.
        """
        return self.link_ns + self.switch_pipe_ns


@dataclasses.dataclass(frozen=True)
class PCSConfig:
    """Full configuration of one simulated system."""

    scheme: Scheme = Scheme.PB
    n_pbe: int = 16              # persistent buffer entries (paper Table I)
    n_switches: int = 1          # CXL switches between CPU and PM
    # Per-switch PBE capacities of the chained pooling topology: entry h
    # is the PB size of switch h+1 (hop 1 = the tenant-facing ack point,
    # deeper hops = the pooling chain).  ``None`` = ``n_pbe`` at every
    # hop.  When set, ``n_pbe`` is synced from entry 0 (one source of
    # truth, like the policy <-> legacy-float shim).  Lowered to a
    # *traced* per-hop vector, so a mixed-depth / mixed-capacity chain
    # sweep stays one XLA program; only the grid-wide max hop count and
    # max capacity are static shapes.
    pbe_per_hop: Optional[Tuple[int, ...]] = None
    n_cores: int = 8             # paper: 8-core OoO
    # Independent hosts (tenants) sharing the switch's persistence domain:
    # the trace's live cores are partitioned into ``n_tenants`` contiguous
    # groups (tenant t owns cores {c : floor(c*T/n_live) == t}) that share
    # the PB slots, the PBC FIFO and the PM banks.  Lowered to a *traced*
    # scalar, so a {workload x scheme x tenant-count} grid is one XLA
    # program; only the per-tenant stats row count is a static shape.
    n_tenants: int = 1
    # Declarative persistence policy (drain-down x allocation).  ``None``
    # builds a default ``PBPolicy`` from the two legacy floats below —
    # the compatibility shim for pre-policy callers; passing ``policy=``
    # wins and the floats are synced from it (one source of truth).
    # Every policy field lowers to a traced scalar / per-tenant vector,
    # so a {workload x scheme x policy} sweep is one XLA program.
    policy: Optional[PBPolicy] = None
    drain_threshold: float = DEFAULT_DRAIN_THRESHOLD
    drain_preset: float = DEFAULT_DRAIN_PRESET
    pm_banks: int = 4             # independent PM device banks (the single
                                  # NVM device of Table I pipelines requests
                                  # across internal banks)
    # Power-loss instant (ns since simulation start).  ``inf`` = no crash.
    # Lowered to a *traced* scalar (engine.state.scalars_from_config), so
    # a crash-point sweep is just another stacked config axis: a
    # {workload x scheme x crash-point} grid stays one XLA program.
    crash_at_ns: float = math.inf
    # Fan-out fabric topology (leaf switches sharing one spine).  ``None``
    # keeps the linear chain.  When set, the tree lowers onto the chain
    # machinery: ``n_switches`` is forced to 2 (leaves are hop 1, the
    # spine is hop 2) and ``pbe_per_hop`` to ``(sum(leaf_pbe),
    # spine_pbe)`` — the leaves partition the hop-1 slot axis.  The
    # descriptor itself lowers to traced scalars/vectors
    # (``n_leaves`` / ``leaf_of_t`` / ``leaf_base`` / ``bp_high``), so a
    # mixed {chain x fabric x placement} grid stays one XLA program.
    fabric: Optional[FabricTopology] = None
    latency: LatencyProfile = dataclasses.field(default_factory=LatencyProfile)

    def __post_init__(self) -> None:
        if self.fabric is not None:
            # Lower the tree onto the chain machinery BEFORE the chain
            # checks below, so they validate the derived values.
            if self.scheme == Scheme.NOPB:
                raise ValueError(
                    "fabric is meaningless under NOPB: a volatile "
                    "fabric has no persistent buffers to place")
            for e in range(n_epochs_of(self.fabric.placement)):
                p = epoch_value(self.fabric.placement, e)
                if len(p) != self.n_tenants:
                    raise ValueError(
                        f"fabric.placement has {len(p)} "
                        f"entries for n_tenants={self.n_tenants}; need "
                        "exactly one leaf id per tenant")
            derived = (sum(self.fabric.leaf_pbe), self.fabric.spine_pbe)
            if self.n_switches not in (1, 2):
                raise ValueError(
                    "a fabric is a two-level tree (leaves + spine, "
                    "n_switches=2); leave n_switches at its default")
            object.__setattr__(self, "n_switches", 2)
            if self.pbe_per_hop is not None and \
                    tuple(int(x) for x in self.pbe_per_hop) != derived:
                raise ValueError(
                    f"pbe_per_hop={self.pbe_per_hop} disagrees with the "
                    f"fabric's derived {derived} (sum of leaf_pbe, "
                    "spine_pbe); drop pbe_per_hop — the fabric owns it")
            object.__setattr__(self, "pbe_per_hop", derived)
        if self.n_pbe < 1:
            raise ValueError("n_pbe must be >= 1")
        if self.n_switches < 0:
            raise ValueError("n_switches must be >= 0")
        if self.n_switches == 0 and self.scheme != Scheme.NOPB:
            # The persistent buffer lives inside the first switch; with no
            # switch in the chain there is nowhere for it to exist, and
            # lowering the drain path to 0 ns would silently simulate a
            # free PB (the old behaviour of scalars_from_config).
            raise ValueError(
                f"scheme {self.scheme.name} requires n_switches >= 1: the "
                "persistent buffer lives in the first CXL switch (use "
                "Scheme.NOPB for the switchless direct-attach baseline)")
        if self.pbe_per_hop is not None:
            if self.scheme == Scheme.NOPB:
                raise ValueError(
                    "pbe_per_hop is meaningless under NOPB: a volatile "
                    "switch chain has no persistent buffers")
            q = tuple(int(x) for x in self.pbe_per_hop)
            if len(q) != self.n_switches:
                raise ValueError(
                    f"pbe_per_hop has {len(q)} entries for "
                    f"n_switches={self.n_switches}; need one per switch")
            if any(x < 1 for x in q):
                raise ValueError("pbe_per_hop entries must be >= 1")
            object.__setattr__(self, "pbe_per_hop", q)
            # hop 1's capacity is the legacy n_pbe (one source of truth)
            object.__setattr__(self, "n_pbe", q[0])
        if not 1 <= self.n_tenants <= self.n_cores:
            raise ValueError("require 1 <= n_tenants <= n_cores")
        if not (0.0 < self.drain_preset <= self.drain_threshold <= 1.0):
            raise ValueError("require 0 < preset <= threshold <= 1")
        if self.policy is None:
            # compat shim: the legacy float knobs forward into a default
            # PBPolicy (DESIGN.md "Policy API"); bit-identical lowering
            object.__setattr__(self, "policy", PBPolicy(
                drain=DrainPolicy(threshold=self.drain_threshold,
                                  preset=self.drain_preset)))
        else:
            # policy wins: sync the legacy floats so threshold_count /
            # preset_count and telemetry read one source of truth (a
            # scheduled threshold/preset syncs its epoch-0 value — the
            # per-epoch counts are lowered from the schedule itself)
            object.__setattr__(self, "drain_threshold",
                               epoch_value(self.policy.drain.threshold, 0))
            object.__setattr__(self, "drain_preset",
                               epoch_value(self.policy.drain.preset, 0))
        self.policy.validate_for(self.n_pbe, self.n_tenants)
        if self.crash_at_ns < 0.0:
            raise ValueError("crash_at_ns must be >= 0 (or inf for no crash)")
        # force the shared-boundary validation at construction time: every
        # scheduled knob of this config must agree on ONE epoch-boundary
        # vector (the engine lowers a single shared epoch axis)
        _ = self.epoch_boundaries

    def with_crash(self, crash_at_ns: float) -> "PCSConfig":
        """Same system, power lost at ``crash_at_ns`` (Section V-D4)."""
        return dataclasses.replace(self, crash_at_ns=crash_at_ns)

    @property
    def epoch_boundaries(self) -> Tuple[float, ...]:
        """The config's shared epoch-boundary vector (``()`` = static).

        Collected across every schedule-capable knob and validated to
        be ONE vector (``shared_boundaries`` raises on disagreement) —
        the engine lowers a single ``epoch_bounds`` operand per config.
        """
        return shared_boundaries(
            self.policy.drain.threshold,
            self.policy.drain.preset,
            self.policy.drain.latency_target_ns,
            self.policy.alloc.tenant_quota,
            self.fabric.placement if self.fabric is not None else None)

    @property
    def n_epochs(self) -> int:
        """Number of schedule epochs (1 = fully static config)."""
        return len(self.epoch_boundaries) + 1

    @property
    def hop_pbes(self) -> Tuple[int, ...]:
        """PBE capacity per switch of the chain (empty for NOPB/depth 0)."""
        if self.scheme == Scheme.NOPB or self.n_switches == 0:
            return ()
        if self.pbe_per_hop is not None:
            return self.pbe_per_hop
        return (self.n_pbe,) * self.n_switches

    @property
    def max_hop_pbe(self) -> int:
        """Largest PB array anywhere in the chain (static shape bound)."""
        return max(self.hop_pbes, default=self.n_pbe)

    @property
    def threshold_count(self) -> int:
        return threshold_count(self.n_pbe, self.drain_threshold)

    @property
    def preset_count(self) -> int:
        return preset_count(self.n_pbe, self.drain_preset)
